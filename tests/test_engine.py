"""RetrievalEngine: admission, micro-batching, maintenance, swap.

Single-device layouts (plain + mutable) are exercised in-process in the
engine's deterministic step mode — no threads, the exact code path the
serve loop runs — so bit-equality assertions are reproducible.  Threaded
behaviour (drain, swap-under-load) uses the real serve/maintenance threads
but keeps all determinism in the assertions: results are compared against
a direct ``index.search`` on the index VERSION (epoch) each ticket ran
against.  The sharded layouts run in the 8-virtual-device subprocess
battery (``scripts/serving_check.py``), mirroring the repo's other
multi-device suites.
"""

import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.index import HilbertIndex, IndexConfig, MutableHilbertIndex
from repro.serve import (
    EngineClosed,
    MaintenancePolicy,
    QueueFull,
    RetrievalEngine,
    pipelined_search,
)

N, D, Q = 2000, 32, 48

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0),
    query_chunk=16,
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return np.asarray(data), np.asarray(queries)


@pytest.fixture(scope="module")
def static_index(dataset):
    data, _ = dataset
    return HilbertIndex.build(data, config=CFG)


def _mutable(data, n=1500):
    mut = MutableHilbertIndex(CFG, buffer_capacity=256, max_segments=8)
    mut.insert(data[:n])
    return mut


# -- step mode: batched results bit-identical to direct search ---------------


def test_step_mode_batches_are_bit_identical_to_direct_search(
    static_index, dataset
):
    """Ragged submissions, micro-batched, split back: every row equals the
    same row of one direct ``index.search`` over the concatenated batch."""
    _, queries = dataset
    direct_i, direct_d = static_index.search(queries, SP)
    eng = RetrievalEngine(static_index, SP, max_batch=16)
    cuts = [0, 5, 8, 20, 21, 37, Q]
    tickets = [
        eng.submit(queries[a:b]) for a, b in zip(cuts[:-1], cuts[1:])
    ]
    while eng.step():
        pass
    got_i = np.concatenate([t.ids for t in tickets])
    got_d = np.concatenate([t.dists for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i))
    np.testing.assert_array_equal(got_d, np.asarray(direct_d))
    # micro-batching actually happened: fewer batches than tickets
    assert eng.metrics.counter("batches") < len(tickets)
    assert eng.metrics.counter("completed") == len(tickets)
    assert all(t.epoch == 0 for t in tickets)


def test_step_mode_on_mutable_layout(dataset):
    data, queries = dataset
    mut = _mutable(data)
    direct_i, direct_d = mut.search(queries, SP)
    eng = RetrievalEngine(mut, SP)
    ids, dists = eng.search(queries)
    np.testing.assert_array_equal(ids, np.asarray(direct_i))
    np.testing.assert_array_equal(dists, np.asarray(direct_d))


def test_params_heterogeneity_splits_batches(static_index, dataset):
    """Requests with different SearchParams never share a micro-batch (and
    both still return the direct-search answer for their params)."""
    _, queries = dataset
    other = SearchParams(k1=16, k2=64, h=1, k=5)
    eng = RetrievalEngine(static_index, SP, max_batch=64)
    t1 = eng.submit(queries[:8], SP)
    t2 = eng.submit(queries[8:16], other)
    while eng.step():
        pass
    assert eng.metrics.counter("batches") == 2
    di, _ = static_index.search(queries[:8], SP)
    np.testing.assert_array_equal(t1.ids, np.asarray(di))
    di2, _ = static_index.search(queries[8:16], other)
    np.testing.assert_array_equal(t2.ids, np.asarray(di2))


def test_pipelined_search_is_bit_identical(static_index, dataset):
    """Double-buffered chunk staging changes timing, never results."""
    _, queries = dataset
    direct_i, direct_d = static_index.search(queries, SP)
    pi, pd = pipelined_search(static_index, queries, SP, query_chunk=16)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(direct_i))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(direct_d))


# -- maintenance + swap ------------------------------------------------------


def test_swap_serves_each_ticket_on_a_consistent_epoch(dataset):
    """Tickets before/after a swap each match a direct search on the index
    version that served them; the swap itself is observable via epoch."""
    data, queries = dataset
    mut = _mutable(data)
    ids0 = mut.insert(data[1500:])          # extra segments to compact
    mut.delete(np.asarray(ids0[:100]))
    eng = RetrievalEngine(mut, SP)
    old_index = eng.index

    t_before = eng.submit(queries)
    while eng.step():
        pass
    swapped = eng.maintain_once(force=True)
    assert swapped and eng.epoch == 1
    assert eng.index is not old_index
    t_after = eng.submit(queries)
    while eng.step():
        pass

    assert t_before.epoch == 0 and t_after.epoch == 1
    # the old index object is never mutated by the swap: a direct search
    # on it still reproduces the pre-swap ticket bit-for-bit
    oi, od = old_index.search(queries, SP)
    np.testing.assert_array_equal(t_before.ids, np.asarray(oi))
    np.testing.assert_array_equal(t_before.dists, np.asarray(od))
    ni, nd = eng.index.search(queries, SP)
    np.testing.assert_array_equal(t_after.ids, np.asarray(ni))
    np.testing.assert_array_equal(t_after.dists, np.asarray(nd))
    assert eng.metrics.counter("swaps") == 1


def test_swap_replays_writes_received_during_shadow_compaction(dataset):
    """Writes landing while the shadow compacts survive the swap with the
    SAME external ids (sequential id assignment makes replay exact)."""
    data, queries = dataset
    mut = _mutable(data, n=1000)
    eng = RetrievalEngine(mut, SP)
    stop = threading.Event()
    inserted = []

    def writer():
        s = 1000
        while not stop.is_set() and s < N:
            inserted.append((s, eng.insert(data[s : s + 50])))
            s += 50

    th = threading.Thread(target=writer)
    th.start()
    try:
        assert eng.maintain_once(force=True)
    finally:
        stop.set()
        th.join()
    stats = eng.maintenance_stats()
    n_written = sum(i.shape[0] for _, i in inserted)
    assert stats["n_live"] == 1000 + n_written
    # replayed ids are the ids the writer observed
    for s, ids in inserted:
        np.testing.assert_array_equal(
            np.asarray(ids), np.arange(s, s + ids.shape[0])
        )
    # and the swapped index actually serves the replayed rows
    ids, _ = eng.search(data[1000:1008])
    assert (np.asarray(ids)[:, 0] == np.arange(1000, 1008)).all()


def test_maintenance_policy_triggers():
    pol = MaintenancePolicy(max_segments=4, max_tombstone_ratio=0.25)
    base = {"n_live": 100, "mergeable_segments": 2}
    assert not pol.triggered({**base, "n_segments": 4, "tombstone_ratio": 0.1})
    assert pol.triggered({**base, "n_segments": 5, "tombstone_ratio": 0.1})
    assert pol.triggered({**base, "n_segments": 2, "tombstone_ratio": 0.3})
    # empty or point-less (store_points=False) indexes never trigger
    assert not pol.triggered({"n_live": 0, "n_segments": 9,
                              "mergeable_segments": 9, "tombstone_ratio": 0.9})
    assert not pol.triggered({"n_live": 100, "n_segments": 9,
                              "mergeable_segments": 0, "tombstone_ratio": 0.9})


def test_static_layouts_serve_read_only(static_index, dataset):
    eng = RetrievalEngine(static_index, SP)
    assert eng.maintain_once(force=True) is False
    assert eng.maintenance_stats() == {}
    with pytest.raises(TypeError, match="immutable"):
        eng.insert(np.zeros((1, D), np.float32))
    with pytest.raises(TypeError, match="immutable"):
        eng.delete(np.asarray([0]))


# -- admission: backpressure + lifecycle -------------------------------------


def test_queue_full_backpressure(static_index, dataset):
    _, queries = dataset
    eng = RetrievalEngine(static_index, SP, max_queue=2)
    eng.submit(queries[:1])
    eng.submit(queries[:1])
    with pytest.raises(QueueFull):
        eng.submit(queries[:1], block=False)
    with pytest.raises(QueueFull):
        eng.submit(queries[:1], timeout=0.02)
    assert eng.metrics.counter("rejected") == 2
    # serving one batch frees capacity
    assert eng.step() > 0
    eng.submit(queries[:1], block=False)


def test_threaded_drain_and_close(static_index, dataset):
    """stop(drain=True) serves every admitted request, then admission is
    closed for good."""
    _, queries = dataset
    eng = RetrievalEngine(static_index, SP, max_batch=8, start=True)
    tickets = [eng.submit(queries[i : i + 3]) for i in range(0, 45, 3)]
    eng.stop(drain=True)
    assert not eng.running
    direct_i, _ = static_index.search(queries[:45], SP)
    got_i = np.concatenate([t.result(0)[0] for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i)[:45])
    with pytest.raises(EngineClosed):
        eng.submit(queries[:1])
    assert eng.metrics.counter("completed") == len(tickets)


def test_stop_without_drain_fails_pending(static_index, dataset):
    _, queries = dataset
    eng = RetrievalEngine(static_index, SP)  # step mode: nothing serves
    t = eng.submit(queries[:4])
    eng.stop(drain=False)
    with pytest.raises(EngineClosed):
        t.result(0)


def test_context_manager_drains(static_index, dataset):
    _, queries = dataset
    with RetrievalEngine(static_index, SP, start=True) as eng:
        t = eng.submit(queries[:4])
    ids, _ = t.result(0)
    di, _ = static_index.search(queries[:4], SP)
    np.testing.assert_array_equal(ids, np.asarray(di))


def test_threaded_swap_under_load_is_epoch_consistent(dataset):
    """Concurrent submit streams + a forced swap: every ticket's results
    are bit-equal to a direct search on the epoch that served it."""
    data, queries = dataset
    mut = _mutable(data)
    mut.insert(data[1500:])
    eng = RetrievalEngine(mut, SP, maintenance=None, start=True)
    old_index = eng.index
    tickets = []
    t_lock = threading.Lock()
    stop = threading.Event()

    def submitter():
        i = 0
        while not stop.is_set():
            t = eng.submit(queries[i % 40 : i % 40 + 4])
            with t_lock:
                tickets.append(t)
            i += 4

    threads = [threading.Thread(target=submitter) for _ in range(2)]
    for th in threads:
        th.start()
    try:
        assert eng.maintain_once(force=True)
    finally:
        stop.set()
        for th in threads:
            th.join()
        eng.stop(drain=True)
    new_index = eng.index
    assert new_index is not old_index
    epochs = set()
    for t in tickets:
        ids, dists = t.result(5)
        epochs.add(t.epoch)
        served_by = old_index if t.epoch == 0 else new_index
        di, dd = served_by.search(t.queries, SP)
        np.testing.assert_array_equal(ids, np.asarray(di))
        np.testing.assert_array_equal(dists, np.asarray(dd))
    assert 1 in epochs  # at least some tickets saw the swapped index


# -- regressions: maintenance exclusion, log lifecycle, admission deadline ---


def test_concurrent_maintenance_cycles_serialize_and_lose_no_writes(dataset):
    """maintain_once is mutually exclusive with itself: a forced cycle
    racing the maintainer thread must serialize on the maintenance mutex.
    Interleaved cycles would clobber each other's replay log (silently
    dropping writes admitted between the two snapshots) and race the
    epoch swap."""
    data, _ = dataset
    mut = _mutable(data, n=1000)
    eng = RetrievalEngine(mut, SP)
    overlap = []
    inside = threading.Semaphore(1)
    orig = eng._maintain_cycle

    def tracked(force):
        if not inside.acquire(blocking=False):
            overlap.append(True)  # two cycles in flight at once: the bug
        try:
            return orig(force)
        finally:
            inside.release()

    eng._maintain_cycle = tracked
    stop = threading.Event()
    inserted = []

    def writer():
        s = 1000
        while not stop.is_set() and s < N:
            inserted.append(eng.insert(data[s : s + 25]))
            s += 25

    wth = threading.Thread(target=writer)
    cycles = [
        threading.Thread(target=eng.maintain_once, kwargs={"force": True})
        for _ in range(2)
    ]
    wth.start()
    for th in cycles:
        th.start()
    for th in cycles:
        th.join()
    stop.set()
    wth.join()
    assert not overlap
    assert eng._write_log is None  # no cycle left the log open
    n_written = sum(i.shape[0] for i in inserted)
    assert eng.maintenance_stats()["n_live"] == 1000 + n_written


def test_catchup_replay_failure_closes_the_write_log(dataset):
    """A replay failure mid-cycle abandons the shadow AND closes the
    replay log — otherwise every later write keeps copying into a log
    nobody will ever drain (unbounded growth on the write path)."""
    data, _ = dataset
    mut = _mutable(data)
    mut.insert(data[1500:])
    eng = RetrievalEngine(mut, SP)
    orig_snapshot = mut.snapshot

    def snap():
        shadow = orig_snapshot()
        orig_compact = shadow.compact

        def compact():
            orig_compact()
            eng.insert(data[:4])  # lands in the open replay log

            def boom(*a, **k):
                raise RuntimeError("shadow replay boom")

            shadow.insert = boom

        shadow.compact = compact
        return shadow

    mut.snapshot = snap
    with pytest.raises(RuntimeError, match="shadow replay boom"):
        eng.maintain_once(force=True)
    assert eng._write_log is None
    assert eng.epoch == 0  # the failed cycle never swapped
    # serving and the write path stay healthy after the abandoned cycle
    eng.insert(data[:1])
    assert eng._write_log is None
    ids, _ = eng.search(data[:8])
    assert np.asarray(ids).shape == (8, SP.k)


def test_submit_timeout_is_a_deadline_not_per_wakeup(static_index, dataset):
    """Wakeups that don't free a slot (another submitter won the race)
    must not restart the admission timeout from scratch."""
    _, queries = dataset
    eng = RetrievalEngine(static_index, SP, max_queue=1)
    eng.submit(queries[:1])  # queue full; step mode, so nothing drains
    stop = threading.Event()

    def noisy_notifier():
        while not stop.is_set():
            with eng._cv:
                eng._cv.notify_all()
            time.sleep(0.02)

    th = threading.Thread(target=noisy_notifier)
    th.start()
    try:
        t0 = time.monotonic()
        with pytest.raises(QueueFull):
            eng.submit(queries[:1], timeout=0.15)
        elapsed = time.monotonic() - t0
    finally:
        stop.set()
        th.join()
    # pre-fix, every notify restarted the full 0.15s wait indefinitely
    assert elapsed < 1.5


def test_serving_engine_reattach_stops_previous_engine(dataset):
    """RetrievalStore.serving_engine() called twice must stop the first
    engine's threads before attaching the replacement — a live orphan
    would keep compacting/swapping an index the store no longer serves."""
    from repro.serve.retrieval import RetrievalStore

    data, _ = dataset
    values = np.arange(1500, dtype=np.int32)
    store = RetrievalStore.build(data[:1500], values, CFG)
    first = store.serving_engine(SP, start=True)
    assert first.running
    second = store.serving_engine(SP)
    assert store.engine is second and second is not first
    assert not first.running and first._maintainer is None
    with pytest.raises(EngineClosed):
        first.submit(data[:1])
    ids, _ = store.lookup(data[:4], SP)
    assert np.asarray(ids).shape == (4, SP.k)


# -- the 8-virtual-device battery (subprocess keeps our device view) ---------


def test_serving_8_devices():
    script = os.path.join(
        os.path.dirname(__file__), "..", "scripts", "serving_check.py"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (
            os.path.join(os.path.dirname(__file__), "..", "src"),
            env.get("PYTHONPATH", ""),
        ) if p
    )
    out = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL SERVING CHECKS PASSED" in out.stdout
