"""Sharded-mutable index: routing/LSM units in-process, mesh parity in a
subprocess.

The multi-device battery lives in ``scripts/sharded_mutable_check.py`` and
runs with 8 simulated devices in a subprocess (this pytest process keeps
its default device view): streamed-vs-fresh-rebuild bit-equality after
compaction, one-dispatch search under churn, skewed-insert/empty-shard
generations, format_version-4 round-trips and v3 adoption/reshard, and the
streaming sharded RetrievalStore.  In-process tests cover the pieces that
don't need a mesh: curve-range routing, the shared LSM id space, the
tombstone k-inflation helper, and config plumbing.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import distributed
from repro.core.search import inflate_k
from repro.core.types import ForestConfig
from repro.index import IndexConfig, LsmIdSpace


# -- curve-range routing (the sharded-mutable write path) --------------------


def test_route_to_shards_respects_partition_bounds():
    # 1-D points on a line: the master Hilbert order IS the coordinate
    # order, so contiguous curve ranges are contiguous intervals.  With
    # bits=6 the 64 grid levels hit the 64 points exactly (key_bits may
    # not exceed d*bits, so 1-D keys are 6 bits wide).
    cfg = ForestConfig(n_trees=1, bits=6, key_bits=6, leaf_size=4)
    pts = np.linspace(0.0, 1.0, 64, dtype=np.float32)[:, None]
    lo, hi = pts.min(0), pts.max(0)
    # shards own [0, .25), [.25, .5), [.5, .75), [.75, 1]
    firsts = [pts[0], pts[16], pts[32], pts[48]]
    bounds = distributed.curve_partition_bounds(firsts, cfg, lo, hi)
    assert bounds.shape[0] == 3
    routes = distributed.route_to_shards(pts, cfg, lo, hi, bounds)
    expect = np.repeat(np.arange(4, dtype=np.int32), 16)
    np.testing.assert_array_equal(routes, expect)
    # out-of-box points clamp to the ends instead of failing
    far = np.asarray([[-5.0], [5.0]], np.float32)
    r = distributed.route_to_shards(far, cfg, lo, hi, bounds)
    assert r[0] == 0 and r[1] == 3


def test_route_agrees_with_hilbert_partition():
    # Frozen bounds recovered from a partition route every partitioned
    # row back to its owning shard (equal-key ties aside — continuous
    # random data makes them measure-zero at these key widths).
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(256, 8)).astype(np.float32)
    cfg = ForestConfig(n_trees=1, bits=4, key_bits=32, leaf_size=4)
    parts = distributed.hilbert_partition(
        __import__("jax").numpy.asarray(pts), cfg, n_shards=4
    )
    lo, hi = pts.min(0), pts.max(0)
    firsts = [pts[p[0]] if len(p) else None for p in parts]
    bounds = distributed.curve_partition_bounds(firsts, cfg, lo, hi)
    owner = np.zeros((256,), np.int32)
    for s, p in enumerate(parts):
        owner[np.asarray(p)] = s
    routes = distributed.route_to_shards(pts, cfg, lo, hi, bounds)
    np.testing.assert_array_equal(owner, routes)


def test_route_empty_shards_get_max_key_bound():
    cfg = ForestConfig(n_trees=1, bits=6, key_bits=6, leaf_size=4)
    pts = np.linspace(0.0, 1.0, 8, dtype=np.float32)[:, None]
    lo, hi = pts.min(0), pts.max(0)
    # shards 2/3 own nothing: their opening keys are MAX, so everything
    # routes to the shards that actually own curve ranges
    bounds = distributed.curve_partition_bounds(
        [pts[0], pts[4], None, None], cfg, lo, hi
    )
    routes = distributed.route_to_shards(pts, cfg, lo, hi, bounds)
    assert routes.max() <= 1


def test_np_lex_ge_matches_tuple_compare():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**32, size=(64, 3), dtype=np.uint32)
    keys[:8, 0] = 7  # force some equal leading words
    bound = keys[5].copy()
    got = distributed._np_lex_ge(keys, bound)
    want = np.asarray([tuple(k) >= tuple(bound) for k in keys])
    np.testing.assert_array_equal(got, want)


# -- shared LSM id space -----------------------------------------------------


def test_lsm_id_space_register_delete_values():
    lsm = LsmIdSpace()
    ids = lsm.register(3, lsm.validate(3, np.asarray([10, 11, 12])))
    np.testing.assert_array_equal(ids, [0, 1, 2])
    assert lsm.track_values is True and lsm.n_live == 3
    with pytest.raises(ValueError):
        lsm.validate(2, None)  # values mode pinned by first insert
    assert lsm.delete([1]) == 1
    assert lsm.delete([1]) == 0  # idempotent
    assert lsm.n_live == 2 and lsm.n_deleted == 1
    with pytest.raises(KeyError):
        lsm.delete([99])
    v = np.asarray(lsm.values_at(np.asarray([[2, -1]])))
    np.testing.assert_array_equal(v, [[12, 0]])


def test_lsm_id_space_failed_validate_mutates_nothing():
    lsm = LsmIdSpace()
    with pytest.raises(ValueError):
        lsm.validate(2, np.zeros((3,)))  # wrong values length
    assert lsm.track_values is None and lsm.next_id == 0


# -- tombstone k inflation ---------------------------------------------------


def test_inflate_k_contract():
    assert inflate_k(10, 0, 100) == 10
    assert inflate_k(10, 7, 100) == 17
    assert inflate_k(10, 500, 100) == 100  # capped at the candidate pool
    assert inflate_k(10, 0, 0) == 1        # floored at 1


# -- config plumbing ---------------------------------------------------------


def test_index_config_mutable_roundtrip():
    cfg = IndexConfig(shards=4, mutable=True)
    d = cfg.to_dict()
    assert d["mutable"] is True
    assert IndexConfig.from_dict(d) == cfg
    # older manifests without the field default to immutable
    del d["mutable"]
    assert IndexConfig.from_dict(d).mutable is False


def test_sharded_mutable_rejects_single_device_mesh():
    from repro.index import ShardedMutableHilbertIndex
    from repro.launch.mesh import data_mesh

    if len(__import__("jax").devices()) > 1:
        pytest.skip("needs a 1-device view")
    with pytest.raises(ValueError, match="multi-device"):
        ShardedMutableHilbertIndex(IndexConfig(), mesh=data_mesh(1))


# -- the 8-virtual-device battery (subprocess keeps our device view) ---------


def test_sharded_mutable_parity_8_devices():
    script = os.path.join(
        os.path.dirname(__file__), "..", "scripts", "sharded_mutable_check.py"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (
            os.path.join(os.path.dirname(__file__), "..", "src"),
            env.get("PYTHONPATH", ""),
        ) if p
    )
    out = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL SHARDED-MUTABLE CHECKS PASSED" in out.stdout
