"""The paper-scale index configs reproduce the paper's RAM budget table."""

from repro.configs import gooaq, pubmed23


def test_pubmed23_budget_matches_paper():
    b = pubmed23.memory_budget_bytes(160)
    # "about 76MB" per compressed tree: our packed order (72 MB) + a
    # 448-bit rank directory (13 MB) lands at 85 MB — same ballpark, the
    # delta is our wider keys vs their compressed BST nodes.
    assert 70e6 < b["per_tree"] < 90e6, b["per_tree"] / 1e6
    # "approximately 1.1 GB" of sketches (23M × 384 bits)
    assert 1.05e9 < b["sketches"] < 1.15e9
    # "compressing the combined memory footprint ... to about 4.5 GB"
    assert 4.2e9 < b["stage2_combined"] < 4.8e9
    # 160 trees + stage 2 sit AT the 16 GB limit (the paper's stated
    # reason more trees were impossible)
    total = b["forest"] + b["stage2_combined"]
    assert 14e9 < total < 18.5e9


def test_table_settings_shapes():
    assert len(pubmed23.TABLE1) == 16 and len(pubmed23.TABLE1_TREES) == 16
    assert all(p.k == 30 for p in pubmed23.TABLE1)
    assert len(gooaq.TABLE2) == 5
    assert all(p.k == 15 for p in gooaq.TABLE2)
    # Table 2 ordering: more orders -> used for higher recall rows
    n = [p.n_orders for p in gooaq.TABLE2]
    assert n == sorted(n)
