"""Kernel↔pipeline integration: search with use_kernels=True is identical."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search
from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.kernels.hamming import hamming_rows

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,k,w", [(1, 4, 3), (7, 33, 12), (130, 16, 14)])
def test_hamming_rows_kernel_matches_oracle(q, k, w):
    a = jnp.asarray(RNG.integers(0, 2**32, (q, w), dtype=np.uint32))
    c = jnp.asarray(RNG.integers(0, 2**32, (q, k, w), dtype=np.uint32))
    got = hamming_rows(a, c, use_kernel=True, interpret=True)
    ref = hamming_rows(a, c, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_search_with_kernels_is_identical():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        1500, 32, 64, n_clusters=8, r=4, seed=0)
    cfg = ForestConfig(n_trees=4, bits=4, key_bits=64, leaf_size=16, seed=0)
    idx = search.build_index(jnp.asarray(data), cfg)
    params = SearchParams(k1=16, k2=64, h=1, k=8)
    ids0, d0 = search.search(idx, jnp.asarray(queries), params, cfg)
    ids1, d1 = search.search(idx, jnp.asarray(queries), params, cfg,
                             use_kernels=True)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6)
