"""Kernel↔pipeline integration: search with use_kernels=True is identical."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search
from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.kernels.hamming import hamming_rows

RNG = np.random.default_rng(0)

# Kernel and XLA paths may accumulate float32 distances in different
# orders, so "identical" is pinned to an explicit tolerance instead of
# exact equality: distances agree within DIST_RTOL/DIST_ATOL, and ids may
# differ ONLY at positions where the reference distances tie within
# TIE_ATOL (either order of a tie is a correct top-k).
DIST_RTOL = 1e-5
DIST_ATOL = 1e-6
TIE_ATOL = 1e-4


@pytest.mark.parametrize("q,k,w", [(1, 4, 3), (7, 33, 12), (130, 16, 14)])
def test_hamming_rows_kernel_matches_oracle(q, k, w):
    # integer popcounts have no accumulation-order freedom: exact equality
    a = jnp.asarray(RNG.integers(0, 2**32, (q, w), dtype=np.uint32))
    c = jnp.asarray(RNG.integers(0, 2**32, (q, k, w), dtype=np.uint32))
    got = hamming_rows(a, c, use_kernel=True, interpret=True)
    ref = hamming_rows(a, c, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _assert_ids_equal_up_to_distance_ties(ids_ref, ids_got, d_ref):
    """Mismatched id positions must sit inside a run of tied distances."""
    ids_ref, ids_got = np.asarray(ids_ref), np.asarray(ids_got)
    d_ref = np.asarray(d_ref)
    mismatch = ids_ref != ids_got
    if not mismatch.any():
        return
    for r, c in zip(*np.nonzero(mismatch)):
        tied = np.isclose(d_ref[r], d_ref[r, c], atol=TIE_ATOL)
        tied_ids = set(ids_ref[r, tied].tolist())
        assert ids_got[r, c] in tied_ids, (
            f"row {r} col {c}: kernel id {ids_got[r, c]} is not among the "
            f"reference ids tied at distance {d_ref[r, c]} "
            f"(ref id {ids_ref[r, c]}, tie set {sorted(tied_ids)})"
        )


def test_search_with_kernels_is_identical():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        1500, 32, 64, n_clusters=8, r=4, seed=0)
    cfg = ForestConfig(n_trees=4, bits=4, key_bits=64, leaf_size=16, seed=0)
    idx = search.build_index(jnp.asarray(data), cfg)
    params = SearchParams(k1=16, k2=64, h=1, k=8)
    ids0, d0 = search.search(idx, jnp.asarray(queries), params, cfg)
    ids1, d1 = search.search(idx, jnp.asarray(queries), params, cfg,
                             use_kernels=True)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=DIST_RTOL, atol=DIST_ATOL)
    _assert_ids_equal_up_to_distance_ties(ids0, ids1, d0)
