"""Sharded index: merge edge cases in-process, mesh parity in a subprocess.

The multi-device parity battery lives in ``scripts/sharded_check.py`` and
runs with 8 simulated devices in a subprocess (this pytest process keeps
its default device view).  In-process tests cover the pieces that don't
need a mesh: the associative ``merge_topk`` contract (including the edge
cases the cross-shard merge leans on) and the 1-shard facade's bit-identity
with the plain fused path.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.search import merge_topk, merge_topk_pair
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    SearchParams,
    ShardedHilbertIndex,
    build_auto,
)
from repro.launch.mesh import data_mesh


# -- merge_topk: the cross-shard / cross-segment merge -----------------------


def test_merge_topk_dedups_duplicate_ids_keeping_min():
    # id 7 appears in three "shards" with different distances (the
    # stale-duplicate case); id 3 appears twice at equal distance (the
    # padding-row case after mutable-index compaction / shard padding).
    ids = jnp.asarray([[7, 3, 9, 7, 3, 7]], jnp.int32)
    d = jnp.asarray([[5.0, 2.0, 1.0, 0.5, 2.0, 4.0]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=4)
    assert out_i.tolist() == [[7, 9, 3, -1]]
    assert out_d.tolist()[0][:3] == [0.5, 1.0, 2.0]
    assert np.isinf(np.asarray(out_d)[0, 3])


def test_merge_topk_k_larger_than_pool_pads():
    # k exceeds every source's candidate pool: tail is id -1 / +inf — the
    # contract the sharded path relies on when k > k2*(2h+1) per shard.
    ids = jnp.asarray([[4, 2], [1, -1]], jnp.int32)
    d = jnp.asarray([[1.0, 0.5], [3.0, 0.1]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=5)
    assert out_i.tolist() == [[2, 4, -1, -1, -1], [1, -1, -1, -1, -1]]
    assert np.isinf(np.asarray(out_d)[0, 2:]).all()
    assert np.isinf(np.asarray(out_d)[1, 1:]).all()


def test_merge_topk_all_invalid_and_nonfinite():
    ids = jnp.asarray([[-1, -1, 5]], jnp.int32)
    d = jnp.asarray([[0.0, 1.0, jnp.inf]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=3)
    assert out_i.tolist() == [[-1, -1, -1]]
    assert np.isinf(np.asarray(out_d)).all()


def test_merge_topk_single_sorted_source_passes_through():
    # A single already-sorted source (the mutable index's one-segment case)
    # must pass through bit-identically, including tie order.
    ids = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    d = jnp.asarray([[0.5, 0.5, 0.7, jnp.inf]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=4)
    assert out_i.tolist() == [[10, 11, 12, -1]]
    np.testing.assert_array_equal(np.asarray(out_d)[0, :3],
                                  np.asarray(d)[0, :3])


# -- merge_topk tree-reduction order invariance ------------------------------
#
# The property the butterfly cross-shard reduction rests on: deflating each
# source to its local top-k and merging pairwise — in ANY bracketing — is
# sorted-distance bit-equal to one flat merge of the full pool, and every
# surviving id carries its minimum distance over all source occurrences.


def _fold_merge(parts, k, order):
    """Fold deflated (ids, dists) parts left / right / balanced."""

    def pair(a, b):
        return merge_topk(
            jnp.concatenate([a[0], b[0]], axis=1),
            jnp.concatenate([a[1], b[1]], axis=1),
            k=k,
        )

    if order == "left":
        acc = parts[0]
        for p in parts[1:]:
            acc = pair(acc, p)
        return acc
    if order == "right":
        acc = parts[-1]
        for p in reversed(parts[:-1]):
            acc = pair(p, acc)
        return acc
    assert order == "balanced"
    while len(parts) > 1:
        nxt = [
            pair(parts[i], parts[i + 1])
            for i in range(0, len(parts) - 1, 2)
        ]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def _check_tree_orders(sources, k):
    """Assert every reduction order matches the flat merge of the pool."""
    flat_i = jnp.concatenate([s[0] for s in sources], axis=1)
    flat_d = jnp.concatenate([s[1] for s in sources], axis=1)
    ref_i, ref_d = merge_topk(flat_i, flat_d, k=k)
    parts = [merge_topk(si, sd, k=k) for si, sd in sources]
    for order in ("left", "right", "balanced"):
        got_i, got_d = _fold_merge(list(parts), k, order)
        # outputs are distance-sorted, so sorted-d2 bit-equality is direct
        np.testing.assert_array_equal(np.asarray(got_d), np.asarray(ref_d))
        # dedup keep-min: every surviving id carries its global minimum
        fi, fd = np.asarray(flat_i), np.asarray(flat_d)
        gi, gd = np.asarray(got_i), np.asarray(got_d)
        for r in range(gi.shape[0]):
            for c in range(k):
                if gi[r, c] < 0:
                    assert np.isinf(gd[r, c])
                    continue
                occ = fd[r][(fi[r] == gi[r, c]) & np.isfinite(fd[r])]
                assert gd[r, c] == occ.min(), (r, c, gi[r, c])


def _random_sources(rng, n_sources, q, k):
    """Candidate pools dense in dup ids, exact ties, ±inf, and -1 padding."""
    out = []
    for _ in range(n_sources):
        c = int(rng.integers(1, 8))
        # small id range forces cross-source duplicates; -1 is padding
        ids = rng.integers(-1, 10, size=(q, c)).astype(np.int32)
        # quantized distances force exact ties, inf forces masked slots
        d = rng.choice(
            [0.25, 0.5, 0.5, 1.0, 2.0, np.inf], size=(q, c)
        ).astype(np.float32)
        out.append((jnp.asarray(ids), jnp.asarray(d)))
    return out


def test_merge_tree_orders_random_battery():
    # Example-based sweep of the same property the hypothesis test walks,
    # so the invariant is exercised even without the dev extra installed.
    rng = np.random.default_rng(7)
    for _ in range(25):
        n_sources = int(rng.integers(1, 6))
        k = int(rng.integers(1, 7))
        _check_tree_orders(_random_sources(rng, n_sources, 2, k), k)


def test_merge_tree_orders_edges():
    inf, k = np.inf, 4
    # all-invalid pools, k > every pool, duplicate ids at equal distance
    sources = [
        (jnp.asarray([[-1, -1]], jnp.int32),
         jnp.asarray([[0.0, inf]], jnp.float32)),
        (jnp.asarray([[3]], jnp.int32), jnp.asarray([[2.0]], jnp.float32)),
        (jnp.asarray([[3, 5]], jnp.int32),
         jnp.asarray([[2.0, inf]], jnp.float32)),
    ]
    _check_tree_orders(sources, k)
    ref_i, ref_d = merge_topk(
        jnp.concatenate([s[0] for s in sources], axis=1),
        jnp.concatenate([s[1] for s in sources], axis=1),
        k=k,
    )
    assert ref_i.tolist() == [[3, -1, -1, -1]]
    assert np.isinf(np.asarray(ref_d)[0, 1:]).all()


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_merge_tree_orders_property(data):
    n_sources = data.draw(st.integers(1, 6), label="n_sources")
    k = data.draw(st.integers(1, 8), label="k")
    q = data.draw(st.integers(1, 3), label="q")
    sources = []
    for _ in range(n_sources):
        c = data.draw(st.integers(1, 7), label="pool")
        ids = data.draw(
            st.lists(
                st.lists(st.integers(-1, 9), min_size=c, max_size=c),
                min_size=q, max_size=q,
            ),
            label="ids",
        )
        dists = data.draw(
            st.lists(
                st.lists(
                    st.sampled_from([0.25, 0.5, 1.0, 1.5, 3.0, np.inf]),
                    min_size=c, max_size=c,
                ),
                min_size=q, max_size=q,
            ),
            label="dists",
        )
        sources.append((
            jnp.asarray(np.asarray(ids, np.int32)),
            jnp.asarray(np.asarray(dists, np.float32)),
        ))
    _check_tree_orders(sources, k)


def test_merge_topk_pair_rank_order_symmetry():
    # Both members of a butterfly pair merge the SAME column layout: the
    # lower rank passes first=True with (mine, theirs), the upper rank
    # first=False with (mine, theirs) — bit-identical outputs.
    rng = np.random.default_rng(3)
    a_i = jnp.asarray(rng.integers(-1, 10, (3, 5)).astype(np.int32))
    a_d = jnp.asarray(
        rng.choice([0.25, 0.5, 1.0, np.inf], (3, 5)).astype(np.float32)
    )
    b_i = jnp.asarray(rng.integers(-1, 10, (3, 5)).astype(np.int32))
    b_d = jnp.asarray(
        rng.choice([0.25, 0.5, 1.0, np.inf], (3, 5)).astype(np.float32)
    )
    lo_i, lo_d = merge_topk_pair(a_i, a_d, b_i, b_d, jnp.bool_(True), k=4)
    hi_i, hi_d = merge_topk_pair(b_i, b_d, a_i, a_d, jnp.bool_(False), k=4)
    np.testing.assert_array_equal(np.asarray(lo_i), np.asarray(hi_i))
    np.testing.assert_array_equal(np.asarray(lo_d), np.asarray(hi_d))


# -- 1-shard facade: bit-identity with the plain fused path ------------------


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        1200, 12, 16, n_clusters=8, seed=1
    )
    return np.asarray(data), jnp.asarray(queries)


CFG = IndexConfig(
    forest=ForestConfig(n_trees=3, bits=4, key_bits=64, leaf_size=16, seed=0)
)
SP = SearchParams(k1=32, k2=64, h=2, k=10)


def test_single_shard_bit_identical_to_fused(dataset):
    data, queries = dataset
    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), CFG, mesh=data_mesh(1)
    )
    plain = HilbertIndex.build(jnp.asarray(data), CFG)
    ids_s, d2_s = sharded.search(queries, SP)
    ids_p, d2_p = plain.search(queries, SP, fused=True)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_p))
    np.testing.assert_array_equal(np.asarray(d2_s), np.asarray(d2_p))
    rep = sharded.memory_report()
    assert rep["n_shards"] == 1
    assert rep["per_device_bytes"] == [rep["resident_bytes"]]


def test_single_shard_save_load_roundtrip(dataset, tmp_path):
    data, queries = dataset
    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), CFG, mesh=data_mesh(1)
    )
    ids, d2 = sharded.search(queries, SP)
    path = os.path.join(str(tmp_path), "ck")
    sharded.save(path)
    loaded = ShardedHilbertIndex.load(path, mesh=data_mesh(1))
    ids2, d22 = loaded.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(d22), np.asarray(d2))


def test_v2_bundle_adopts_as_single_shard(dataset, tmp_path):
    data, queries = dataset
    plain = HilbertIndex.build(jnp.asarray(data), CFG)
    path = os.path.join(str(tmp_path), "v2")
    plain.save(path)
    adopted = ShardedHilbertIndex.load(path, mesh=data_mesh(1))
    assert adopted.n_shards == 1
    np.testing.assert_array_equal(
        np.asarray(adopted.search(queries, SP)[0]),
        np.asarray(plain.search(queries, SP)[0]),
    )


def test_build_auto_picks_by_device_count(dataset):
    data, _ = dataset
    got = build_auto(jnp.asarray(data), CFG)
    if jax.device_count() > 1:
        assert isinstance(got, ShardedHilbertIndex)
        assert got.n_shards == jax.device_count()
    else:
        assert isinstance(got, HilbertIndex)
    # shards=1 forces single-device regardless of the host
    import dataclasses

    forced = build_auto(
        jnp.asarray(data), dataclasses.replace(CFG, shards=1)
    )
    assert isinstance(forced, HilbertIndex)


def test_index_config_shards_roundtrip():
    cfg = IndexConfig(shards=4)
    assert IndexConfig.from_dict(cfg.to_dict()) == cfg
    assert IndexConfig.from_dict(IndexConfig().to_dict()).shards is None


def test_index_config_merge_knobs_roundtrip():
    cfg = IndexConfig(merge="tree", merge_prune=True)
    assert IndexConfig.from_dict(cfg.to_dict()) == cfg
    # manifests from before the merge knobs existed load with defaults
    old = IndexConfig().to_dict()
    del old["merge"], old["merge_prune"]
    loaded = IndexConfig.from_dict(old)
    assert loaded.merge == "auto" and loaded.merge_prune is False


def test_resolve_merge_policy():
    from repro.core.distributed import resolve_merge

    assert resolve_merge("auto", 8) == "tree"
    assert resolve_merge("auto", 6) == "gather"
    assert resolve_merge("auto", 1) == "tree"
    assert resolve_merge("gather", 6) == "gather"
    assert resolve_merge("tree", 4) == "tree"
    with pytest.raises(ValueError):
        resolve_merge("tree", 6)
    with pytest.raises(ValueError):
        resolve_merge("butterfly", 8)


# -- shared bounded dispatch cache -------------------------------------------


def test_bounded_jit_cache_lru_eviction():
    from repro.index.facade import BoundedJitCache

    cache = BoundedJitCache(max_entries=3)
    for key in ("a", "b", "c"):
        cache.put(key, key.upper())
    assert len(cache) == 3
    assert cache.get("a") == "A"  # refreshes recency
    cache.put("d", "D")           # evicts "b", the least recently used
    assert "b" not in cache and cache.get("b") is None
    assert {"a", "c", "d"} == {k for k in ("a", "c", "d") if k in cache}
    cache.clear()
    assert len(cache) == 0
    with pytest.raises(ValueError):
        BoundedJitCache(max_entries=0)


def test_sharded_facade_uses_bounded_cache(dataset):
    # sharded.py historically kept one executable per shape FOREVER while
    # sharded_mutable.py bounded its cache — both now share the LRU cache
    # (the mutable side is asserted in scripts/sharded_mutable_check.py,
    # which can actually build one: it needs a multi-device mesh).
    from repro.index.facade import BoundedJitCache

    data, _ = dataset
    static = ShardedHilbertIndex.build(jnp.asarray(data), CFG,
                                       mesh=data_mesh(1))
    assert isinstance(static._chunk_fns, BoundedJitCache)


# -- multi-device parity battery (subprocess, 8 simulated devices) -----------


def test_sharded_parity_8_devices():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "sharded_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert r.returncode == 0, (
        f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-3000:]}"
    )
    assert "ALL SHARDED CHECKS PASSED" in r.stdout
