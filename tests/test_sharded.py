"""Sharded index: merge edge cases in-process, mesh parity in a subprocess.

The multi-device parity battery lives in ``scripts/sharded_check.py`` and
runs with 8 simulated devices in a subprocess (this pytest process keeps
its default device view).  In-process tests cover the pieces that don't
need a mesh: the associative ``merge_topk`` contract (including the edge
cases the cross-shard merge leans on) and the 1-shard facade's bit-identity
with the plain fused path.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.search import merge_topk
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    SearchParams,
    ShardedHilbertIndex,
    build_auto,
)
from repro.launch.mesh import data_mesh


# -- merge_topk: the cross-shard / cross-segment merge -----------------------


def test_merge_topk_dedups_duplicate_ids_keeping_min():
    # id 7 appears in three "shards" with different distances (the
    # stale-duplicate case); id 3 appears twice at equal distance (the
    # padding-row case after mutable-index compaction / shard padding).
    ids = jnp.asarray([[7, 3, 9, 7, 3, 7]], jnp.int32)
    d = jnp.asarray([[5.0, 2.0, 1.0, 0.5, 2.0, 4.0]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=4)
    assert out_i.tolist() == [[7, 9, 3, -1]]
    assert out_d.tolist()[0][:3] == [0.5, 1.0, 2.0]
    assert np.isinf(np.asarray(out_d)[0, 3])


def test_merge_topk_k_larger_than_pool_pads():
    # k exceeds every source's candidate pool: tail is id -1 / +inf — the
    # contract the sharded path relies on when k > k2*(2h+1) per shard.
    ids = jnp.asarray([[4, 2], [1, -1]], jnp.int32)
    d = jnp.asarray([[1.0, 0.5], [3.0, 0.1]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=5)
    assert out_i.tolist() == [[2, 4, -1, -1, -1], [1, -1, -1, -1, -1]]
    assert np.isinf(np.asarray(out_d)[0, 2:]).all()
    assert np.isinf(np.asarray(out_d)[1, 1:]).all()


def test_merge_topk_all_invalid_and_nonfinite():
    ids = jnp.asarray([[-1, -1, 5]], jnp.int32)
    d = jnp.asarray([[0.0, 1.0, jnp.inf]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=3)
    assert out_i.tolist() == [[-1, -1, -1]]
    assert np.isinf(np.asarray(out_d)).all()


def test_merge_topk_single_sorted_source_passes_through():
    # A single already-sorted source (the mutable index's one-segment case)
    # must pass through bit-identically, including tie order.
    ids = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    d = jnp.asarray([[0.5, 0.5, 0.7, jnp.inf]], jnp.float32)
    out_i, out_d = merge_topk(ids, d, k=4)
    assert out_i.tolist() == [[10, 11, 12, -1]]
    np.testing.assert_array_equal(np.asarray(out_d)[0, :3],
                                  np.asarray(d)[0, :3])


# -- 1-shard facade: bit-identity with the plain fused path ------------------


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        1200, 12, 16, n_clusters=8, seed=1
    )
    return np.asarray(data), jnp.asarray(queries)


CFG = IndexConfig(
    forest=ForestConfig(n_trees=3, bits=4, key_bits=64, leaf_size=16, seed=0)
)
SP = SearchParams(k1=32, k2=64, h=2, k=10)


def test_single_shard_bit_identical_to_fused(dataset):
    data, queries = dataset
    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), CFG, mesh=data_mesh(1)
    )
    plain = HilbertIndex.build(jnp.asarray(data), CFG)
    ids_s, d2_s = sharded.search(queries, SP)
    ids_p, d2_p = plain.search(queries, SP, fused=True)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_p))
    np.testing.assert_array_equal(np.asarray(d2_s), np.asarray(d2_p))
    rep = sharded.memory_report()
    assert rep["n_shards"] == 1
    assert rep["per_device_bytes"] == [rep["resident_bytes"]]


def test_single_shard_save_load_roundtrip(dataset, tmp_path):
    data, queries = dataset
    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), CFG, mesh=data_mesh(1)
    )
    ids, d2 = sharded.search(queries, SP)
    path = os.path.join(str(tmp_path), "ck")
    sharded.save(path)
    loaded = ShardedHilbertIndex.load(path, mesh=data_mesh(1))
    ids2, d22 = loaded.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(d22), np.asarray(d2))


def test_v2_bundle_adopts_as_single_shard(dataset, tmp_path):
    data, queries = dataset
    plain = HilbertIndex.build(jnp.asarray(data), CFG)
    path = os.path.join(str(tmp_path), "v2")
    plain.save(path)
    adopted = ShardedHilbertIndex.load(path, mesh=data_mesh(1))
    assert adopted.n_shards == 1
    np.testing.assert_array_equal(
        np.asarray(adopted.search(queries, SP)[0]),
        np.asarray(plain.search(queries, SP)[0]),
    )


def test_build_auto_picks_by_device_count(dataset):
    data, _ = dataset
    got = build_auto(jnp.asarray(data), CFG)
    if jax.device_count() > 1:
        assert isinstance(got, ShardedHilbertIndex)
        assert got.n_shards == jax.device_count()
    else:
        assert isinstance(got, HilbertIndex)
    # shards=1 forces single-device regardless of the host
    import dataclasses

    forced = build_auto(
        jnp.asarray(data), dataclasses.replace(CFG, shards=1)
    )
    assert isinstance(forced, HilbertIndex)


def test_index_config_shards_roundtrip():
    cfg = IndexConfig(shards=4)
    assert IndexConfig.from_dict(cfg.to_dict()) == cfg
    assert IndexConfig.from_dict(IndexConfig().to_dict()).shards is None


# -- multi-device parity battery (subprocess, 8 simulated devices) -----------


def test_sharded_parity_8_devices():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "sharded_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert r.returncode == 0, (
        f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-3000:]}"
    )
    assert "ALL SHARDED CHECKS PASSED" in r.stdout
