"""Property + example tests for EDF micro-batch formation.

:func:`repro.serve.batching.form_batch` is pure — Hypothesis drives it
directly (no engine, no clock, no threads) and asserts the scheduling
invariants the serving engine relies on: EDF order, expiry shedding
before dispatch, params homogeneity, input conservation, and the
no-starvation fairness bound for deadline-less tickets.  The
example-based tests in the same module run even without hypothesis
installed (see ``_hypothesis_compat``).
"""

import itertools

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.serve.batching import (
    BatchPlan,
    effective_deadline,
    form_batch,
)

HORIZON = 60.0


class _Q:
    """The duck-typed slice of ``queries`` that form_batch reads."""

    def __init__(self, rows):
        self.shape = (rows, 4)


class Ticket:
    _seq = itertools.count()

    def __init__(self, rows=1, params="p", deadline=None,
                 submitted_mono=0.0, seq=None):
        self.queries = _Q(rows)
        self.params = params
        self.deadline = deadline
        self.submitted_mono = submitted_mono
        self.seq = next(self._seq) if seq is None else seq

    def __repr__(self):
        return (f"Ticket(rows={self.queries.shape[0]}, "
                f"params={self.params!r}, deadline={self.deadline}, "
                f"sub={self.submitted_mono}, seq={self.seq})")


def tickets_strategy():
    """Random queues: small rows, two params classes, mixed deadlines."""
    one = st.builds(
        Ticket,
        rows=st.integers(min_value=1, max_value=8),
        params=st.sampled_from(["a", "b"]),
        deadline=st.one_of(
            st.none(),
            st.floats(min_value=-50.0, max_value=150.0,
                      allow_nan=False, allow_infinity=False),
        ),
        submitted_mono=st.floats(min_value=0.0, max_value=100.0,
                                 allow_nan=False, allow_infinity=False),
    )
    return st.lists(one, min_size=0, max_size=24)


# -- properties (hypothesis) -------------------------------------------------


@given(pending=tickets_strategy(),
       max_rows=st.integers(min_value=1, max_value=16),
       now=st.floats(min_value=0.0, max_value=120.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_partition_conservation(pending, max_rows, now):
    """batch + expired + remaining is EXACTLY the input; no overlaps."""
    plan = form_batch(pending, max_rows=max_rows, now=now,
                      no_deadline_horizon=HORIZON)
    taken = [id(t) for t in plan.batch] + [id(t) for t in plan.expired]
    assert len(taken) == len(set(taken))  # disjoint
    assert set(taken) <= {id(t) for t in pending}
    remaining = [t for t in pending if id(t) not in set(taken)]
    assert len(remaining) + len(taken) == len(pending)


@given(pending=tickets_strategy(),
       max_rows=st.integers(min_value=1, max_value=16),
       now=st.floats(min_value=0.0, max_value=120.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_expired_shed_before_dispatch(pending, max_rows, now):
    """``expired`` is exactly the past-deadline set; none are batched."""
    plan = form_batch(pending, max_rows=max_rows, now=now,
                      no_deadline_horizon=HORIZON)
    want = {id(t) for t in pending
            if t.deadline is not None and now > t.deadline}
    assert {id(t) for t in plan.expired} == want
    assert not ({id(t) for t in plan.batch} & want)


@given(pending=tickets_strategy(),
       max_rows=st.integers(min_value=1, max_value=16),
       now=st.floats(min_value=0.0, max_value=120.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_params_homogeneous_and_edf_prefix(pending, max_rows, now):
    """One batch = one params class, taken in EDF order within the class.

    The batch must be precisely the row-capped prefix of the lead's
    class in effective-deadline order (seq tie-break) — skipping a
    nearer-deadline same-class ticket for a later one is an EDF
    violation.
    """
    plan = form_batch(pending, max_rows=max_rows, now=now,
                      no_deadline_horizon=HORIZON)
    if not plan.batch:
        return
    lead = plan.batch[0]
    assert all(t.params == lead.params for t in plan.batch)

    def key(t):
        return (effective_deadline(t, HORIZON), t.seq)

    live = [t for t in pending
            if not (t.deadline is not None and now > t.deadline)]
    assert key(lead) == min(key(t) for t in live)  # global EDF lead
    cls = sorted((t for t in live if t.params == lead.params), key=key)
    expect, rows = [], 0
    for t in cls:
        r = t.queries.shape[0]
        if expect and rows + r > max_rows:
            break
        expect.append(t)
        rows += r
    assert [id(t) for t in plan.batch] == [id(t) for t in expect]


@given(pending=tickets_strategy(),
       max_rows=st.integers(min_value=1, max_value=16),
       now=st.floats(min_value=0.0, max_value=120.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_row_cap_with_lead_exemption(pending, max_rows, now):
    """rows <= max_rows unless a single oversized lead dispatches alone."""
    plan = form_batch(pending, max_rows=max_rows, now=now,
                      no_deadline_horizon=HORIZON)
    if plan.rows > max_rows:
        assert len(plan.batch) == 1


@given(n_rounds=st.integers(min_value=1, max_value=50),
       urgency=st.floats(min_value=0.1, max_value=5.0, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_no_starvation_fairness_bound(n_rounds, urgency):
    """A deadline-less ticket outlives any stream of urgent arrivals.

    Simulation: one deadline-less ticket submitted at t=0 competes
    against a fresh urgent ticket (relative deadline ``urgency``) every
    second, ``max_rows=1`` so only one wins per round.  Its effective
    deadline is the horizon, so once the urgent arrivals' deadlines pass
    the horizon it MUST lead — it is served no later than
    ``horizon + 1`` seconds after submission, the fairness bound.
    """
    horizon = 10.0
    patient = Ticket(rows=1, params="p", deadline=None, submitted_mono=0.0)
    queue = [patient]
    served_at = None
    for step in range(n_rounds):
        now = float(step)
        queue.append(Ticket(rows=1, params="p", deadline=now + urgency,
                            submitted_mono=now))
        plan = form_batch(queue, max_rows=1, now=now,
                          no_deadline_horizon=horizon)
        gone = {id(t) for t in plan.batch} | {id(t) for t in plan.expired}
        if any(t is patient for t in plan.batch):
            served_at = now
            break
        queue = [t for t in queue if id(t) not in gone]
    if n_rounds > horizon + 1:
        assert served_at is not None and served_at <= horizon + 1.0


@given(subs=st.lists(
    st.floats(min_value=0.0, max_value=9.0, allow_nan=False),
    min_size=2, max_size=8,
))
@settings(max_examples=100, deadline=None)
def test_deadline_ties_break_by_seq(subs):
    """Equal effective deadlines dispatch in admission (seq) order."""
    pending = [Ticket(rows=1, params="p", deadline=100.0, submitted_mono=s)
               for s in subs]
    plan = form_batch(pending, max_rows=len(pending), now=0.0,
                      no_deadline_horizon=HORIZON)
    seqs = [t.seq for t in plan.batch]
    assert seqs == sorted(seqs)


# -- examples (always run, no hypothesis needed) -----------------------------


def test_edf_reorders_past_a_bulk_head():
    """The FIFO failure mode: a long-deadline bulk scan at the queue head
    no longer blocks a short-deadline interactive request behind it."""
    bulk = Ticket(rows=4, params="p", deadline=500.0, submitted_mono=0.0)
    urgent = Ticket(rows=1, params="p", deadline=1.0, submitted_mono=0.5)
    plan = form_batch([bulk, urgent], max_rows=4, now=0.6)
    assert plan.batch[0] is urgent
    assert plan.expired == ()


def test_different_params_class_waits_without_blocking():
    """A different-params ticket between two same-class ones is skipped
    (waits its turn), not allowed to end the batch early."""
    a1 = Ticket(rows=1, params="a", deadline=1.0)
    b = Ticket(rows=1, params="b", deadline=2.0)
    a2 = Ticket(rows=1, params="a", deadline=3.0)
    plan = form_batch([a1, b, a2], max_rows=8, now=0.0)
    assert [t is x for t, x in zip(plan.batch, (a1, a2))] == [True, True]
    assert len(plan.batch) == 2


def test_expired_are_shed_not_batched():
    dead = Ticket(rows=1, params="p", deadline=1.0)
    live = Ticket(rows=1, params="p", deadline=9.0)
    plan = form_batch([dead, live], max_rows=8, now=5.0)
    assert plan.expired == (dead,)
    assert plan.batch == (live,)


def test_oversized_lead_dispatches_alone():
    big = Ticket(rows=32, params="p", deadline=1.0)
    small = Ticket(rows=1, params="p", deadline=2.0)
    plan = form_batch([big, small], max_rows=8, now=0.0)
    assert plan.batch == (big,)
    assert plan.rows == 32


def test_row_overflow_stops_within_class_preserving_edf():
    """A same-class ticket that does not fit ENDS the batch — taking a
    later-deadline smaller one instead would violate EDF order."""
    t1 = Ticket(rows=4, params="p", deadline=1.0)
    t2 = Ticket(rows=8, params="p", deadline=2.0)  # overflows
    t3 = Ticket(rows=1, params="p", deadline=3.0)  # would fit, but later
    plan = form_batch([t1, t2, t3], max_rows=8, now=0.0)
    assert plan.batch == (t1,)


def test_deadline_less_tickets_age_under_horizon():
    old = Ticket(rows=1, params="p", deadline=None, submitted_mono=0.0)
    fresh = Ticket(rows=1, params="p", deadline=70.0, submitted_mono=50.0)
    # old's effective deadline is 0 + 60 < 70: it leads despite no deadline
    plan = form_batch([fresh, old], max_rows=1, now=50.0,
                      no_deadline_horizon=60.0)
    assert plan.batch == (old,)


def test_empty_and_all_expired_inputs():
    assert form_batch([], max_rows=4, now=0.0) == BatchPlan((), ())
    dead = Ticket(rows=1, params="p", deadline=1.0)
    plan = form_batch([dead], max_rows=4, now=2.0)
    assert plan.batch == () and plan.expired == (dead,)


def test_max_rows_validation():
    with pytest.raises(ValueError):
        form_batch([], max_rows=0, now=0.0)


def test_effective_deadline():
    assert effective_deadline(Ticket(deadline=5.0)) == 5.0
    assert effective_deadline(
        Ticket(deadline=None, submitted_mono=2.0), 60.0
    ) == 62.0


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_present_marker():
    """CI's concurrency-stress job installs hypothesis; this canary fails
    collection there if the property tests above silently skipped."""
    assert HAVE_HYPOTHESIS
