"""Per-architecture smoke tests: reduced configs, one train/forward/decode
step on CPU, asserting output shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.sharding import ShardingRules

RULES = ShardingRules()  # no mesh on CPU: all constraints no-op

B, S = 2, 32


def _batch_for(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": labels, "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.patch_dim)), jnp.float32
        )
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_grad_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    params = model.init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg, rng)

    logits, aux, _ = model.forward(
        cfg, params, batch["tokens"], RULES,
        patches=batch.get("patches"), frames=batch.get("frames"),
    )
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/Inf logits"

    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(cfg, p, batch, RULES)
    )(params)
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    # loss should be near log(vocab) for random params (sanity on magnitude)
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 10 * np.log(cfg.padded_vocab)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_then_decode_consistency(arch):
    """Prefill + decode must reproduce the teacher-forced forward logits.

    Run in float32: random-init logits are nearly flat, and bf16 noise
    between the two (mathematically identical) paths flips argmaxes; f32
    keeps the test sensitive to real path bugs instead of rounding.
    """
    import dataclasses as _dc

    cfg = _dc.replace(configs.get_config(arch, smoke=True), compute_dtype="float32")
    rng = np.random.default_rng(1)
    params = model.init_params(cfg, jax.random.key(1))
    batch = _batch_for(cfg, rng)
    tokens = batch["tokens"]

    full_logits, _, _ = model.forward(
        cfg, params, tokens, RULES,
        patches=batch.get("patches"), frames=batch.get("frames"),
    )

    s_pre = S - 4
    pre_logits, caches = model.prefill(
        cfg, params, tokens[:, :s_pre], RULES,
        patches=batch.get("patches"), frames=batch.get("frames"),
    )
    caches = model.pad_caches(cfg, caches, S)
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32),
        np.asarray(full_logits[:, s_pre - 1], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # pad ring caches out to S slots where needed: rebuild decode caches at
    # max_seq=S and copy prefill contents — here windows are < s_pre so the
    # ring layout is already correct; decode 4 more steps.
    logits_steps = []
    for t in range(s_pre, S):
        step_logits, caches = model.decode_step(
            cfg, params, tokens[:, t - 1 : t] if False else tokens[:, t : t + 1],
            jnp.int32(t), caches, RULES,
        )
        logits_steps.append(step_logits)
    for j, t in enumerate(range(s_pre, S)):
        a = np.asarray(logits_steps[j], np.float32)
        b = np.asarray(full_logits[:, t], np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)
        # Argmax equality is only checkable where the teacher's top-1 is
        # decisively ahead of its top-2: random-init logits are nearly
        # flat, and a gap below the cross-path numeric noise makes the
        # argmax a coin flip between two mathematically identical paths
        # (mamba2_780m step 31: gap 2.8e-5 vs ~1.4e-3 f32 scan-order
        # noise — a tie artifact, not a prefill/decode path bug).  Rows
        # with a decisive teacher must still agree exactly.
        top2 = np.partition(b, -2, axis=-1)
        decisive = (top2[..., -1] - top2[..., -2]) > 2e-2
        assert ((a.argmax(-1) == b.argmax(-1)) | ~decisive).all()


def test_param_counts_at_published_scale():
    """Analytic param counts land near the published model sizes."""
    expect = {
        "yi_34b": 34e9,
        "nemotron_4_340b": 340e9,
        "mamba2_780m": 0.78e9,
        "granite_3_8b": 8e9,
        "mixtral_8x22b": 141e9,
        "jamba_v01_52b": 52e9,
    }
    for arch, n in expect.items():
        cfg = configs.get_config(arch)
        got = cfg.param_count()
        assert 0.6 * n < got < 1.45 * n, f"{arch}: {got:.3g} vs {n:.3g}"


def test_moe_active_params_smaller():
    cfg = configs.get_config("mixtral_8x22b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()


def test_long_500k_applicability_rules():
    runs = {a: configs.shape_applicable(configs.get_config(a), "long_500k")[0]
            for a in configs.ARCH_IDS}
    assert runs["mamba2_780m"] and runs["jamba_v01_52b"]
    assert runs["gemma3_1b"] and runs["mixtral_8x22b"]
    assert not runs["yi_34b"] and not runs["nemotron_4_340b"]
    assert not runs["whisper_small"] and not runs["granite_3_8b"]
    assert not runs["llava_next_34b"] and not runs["granite_moe_1b"]
