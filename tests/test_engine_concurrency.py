"""Concurrency stress + read-path purity for the reader-writer serving path.

Three layers, all deterministic in their ASSERTIONS even where threads
race freely in between:

* ``ReadWriteLock`` unit semantics — shared readers, exclusive writers,
  write re-entrancy, read-under-write, upgrade refusal, writer
  preference (what makes the serve path starvation-free for swaps).
* Read-path purity — the precondition for the shared read side: a
  facade's ``search(..., allow_rewrite=False)`` must not mutate ANY
  internal state once warm (fingerprinted field-by-field before/after a
  concurrent hammering).  Exemptions are documented where declared:
  ``last_dispatch_count`` (a diagnostic scalar assigned once per search)
  and jit-cache recency ORDER (``BoundedJitCache.keys()`` is
  fingerprinted as a set).
* The stress battery — barrier-started reader threads + a paced writer +
  forced maintenance through >= 3 epoch swaps: every ticket is acked
  (zero drops), probe-window tickets are bit-equal to a direct search on
  the exact index version (epoch) that served them, and the
  ``deadlock_watchdog`` fixture (tests/conftest.py) turns any
  lock-ordering hang into a full thread dump instead of a silent CI
  timeout.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.index import IndexConfig, MutableHilbertIndex
from repro.serve import RetrievalEngine
from repro.serve.rwlock import ReadWriteLock

N, D, Q = 2000, 32, 48

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0),
    query_chunk=16,
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return np.asarray(data), np.asarray(queries)


def _mutable(data, n=1200, deletes=True):
    mut = MutableHilbertIndex(CFG, buffer_capacity=256, max_segments=8)
    ids = mut.insert(data[:n])
    if deletes:
        mut.delete(ids[::7])  # tombstones: dead-count caches get exercised
    return mut


# -- ReadWriteLock semantics -------------------------------------------------


def test_rwlock_readers_share():
    lock = ReadWriteLock()
    inside = threading.Barrier(3, action=lambda: None)

    def reader():
        with lock.read_locked():
            inside.wait(timeout=10)  # all 3 hold the read side AT ONCE

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads)
    assert lock.readers == 0


def test_rwlock_writer_excludes_readers():
    lock = ReadWriteLock()
    observed = []
    entered = threading.Event()
    release = threading.Event()

    def writer():
        with lock.write_locked():
            entered.set()
            release.wait(10)
            observed.append("write-exit")

    def reader():
        entered.wait(10)
        with lock.read_locked():
            observed.append("read")

    tw = threading.Thread(target=writer)
    tr = threading.Thread(target=reader)
    tw.start()
    tr.start()
    entered.wait(10)
    time.sleep(0.05)  # give the reader time to (wrongly) slip in
    assert observed == []  # reader is blocked out
    release.set()
    tw.join(10)
    tr.join(10)
    assert observed == ["write-exit", "read"]


def test_rwlock_write_reentrancy_and_read_under_write():
    lock = ReadWriteLock()
    with lock.write_locked():
        assert lock.write_held()
        with lock.write_locked():       # re-entrant write
            with lock.read_locked():    # read under own write: allowed
                assert lock.write_held()
    assert not lock.write_held()
    assert lock.readers == 0


def test_rwlock_upgrade_refused():
    lock = ReadWriteLock()
    with lock.read_locked():
        with pytest.raises(RuntimeError, match="upgrade"):
            lock.acquire_write()
    # the failed upgrade must not have corrupted state
    with lock.write_locked():
        pass


def test_rwlock_writer_preference_gates_new_readers():
    """A PENDING writer blocks new readers (swaps cannot be starved by a
    steady reader stream), while already-reading threads re-enter freely."""
    lock = ReadWriteLock()
    r1_in = threading.Event()
    r1_go = threading.Event()
    w_done = threading.Event()
    order = []

    def long_reader():
        with lock.read_locked():
            r1_in.set()
            r1_go.wait(10)
            with lock.read_locked():  # re-entrant: bypasses the writer gate
                order.append("reentrant-read")

    def writer():
        with lock.write_locked():
            order.append("write")
        w_done.set()

    def late_reader():
        # arrives while the writer is pending: must wait BEHIND it
        with lock.read_locked():
            order.append("late-read")

    t1 = threading.Thread(target=long_reader)
    t1.start()
    r1_in.wait(10)
    tw = threading.Thread(target=writer)
    tw.start()
    while lock.stats()["pending_writers"] == 0:
        time.sleep(0.001)
    t3 = threading.Thread(target=late_reader)
    t3.start()
    time.sleep(0.05)
    assert "late-read" not in order  # gated by the pending writer
    r1_go.set()
    for t in (t1, tw, t3):
        t.join(10)
    assert order.index("write") < order.index("late-read")
    assert "reentrant-read" in order


def test_rwlock_stats_accounting():
    lock = ReadWriteLock()
    with lock.write_locked():
        time.sleep(0.01)
    with lock.read_locked():
        s = lock.stats()
        assert s["readers"] == 1
    s = lock.stats()
    assert s["read_acquisitions"] >= 1
    assert s["write_acquisitions"] >= 1
    assert s["write_held_ms"] >= 5.0


# -- read-path purity --------------------------------------------------------


def _fingerprint_mutable(idx):
    """Every mutable field the search path could conceivably touch.

    ``seg.dead_cache``/``dead_epoch`` ARE included: the warm-up search
    fills them, after which a pure read path must leave them fixed.
    """
    lsm = idx._lsm
    segs = tuple(
        (id(seg), seg.gen, seg.n_valid, seg.dead_cache, seg.dead_epoch,
         id(seg.index), seg.ids.tobytes())
        for seg in idx.segments
    )
    return (
        int(idx._buf_count), int(idx._gen), int(lsm.next_id),
        int(lsm.delete_epoch), lsm.alive.tobytes(),
        None if idx._buf_points is None else idx._buf_points.tobytes(),
        None if idx._buf_ids is None else idx._buf_ids.tobytes(),
        segs,
    )


def _hammer(search_fn, n_threads=4, n_iters=6):
    """Run ``search_fn(thread_idx, iter_idx)`` from N barrier-started
    threads; returns collected results, raises on any thread error."""
    barrier = threading.Barrier(n_threads)
    errors = []
    results = [[] for _ in range(n_threads)]

    def run(i):
        try:
            barrier.wait(timeout=30)
            for j in range(n_iters):
                results[i].append(search_fn(i, j))
        except BaseException as e:  # surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=run, args=(i,)) for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not any(t.is_alive() for t in threads), "hammer threads hung"
    if errors:
        raise errors[0]
    return results


def test_mutable_read_path_is_pure_under_concurrency(dataset):
    data, queries = dataset
    idx = _mutable(data)
    # warm up: fills dead-count caches and compiles dispatches
    want_i, want_d = idx.search(queries, SP, allow_rewrite=False)
    want_i, want_d = np.asarray(want_i), np.asarray(want_d)
    before = _fingerprint_mutable(idx)

    def do_search(i, j):
        ids, dists = idx.search(queries, SP, allow_rewrite=False)
        return np.asarray(ids), np.asarray(dists)

    results = _hammer(do_search)
    assert _fingerprint_mutable(idx) == before
    for per_thread in results:
        for ids, dists in per_thread:
            np.testing.assert_array_equal(ids, want_i)
            np.testing.assert_array_equal(dists, want_d)


def test_mutable_rewrite_suppression_surfaces_as_pressure(dataset):
    """allow_rewrite=False must not shrink segments even under heavy
    tombstone pressure — the condition surfaces via rewrite_pressure()
    for the maintenance path instead."""
    data, _ = dataset
    idx = MutableHilbertIndex(CFG, buffer_capacity=64, max_segments=8)
    ids = idx.insert(data[:256])
    idx.delete(ids[:200])  # most of every segment is dead
    tight = SearchParams(k1=16, k2=4, h=1, k=4)  # tiny candidate pool
    assert idx.rewrite_pressure(tight) > 0
    before = _fingerprint_mutable(idx)
    idx.search(data[:8], tight, allow_rewrite=False)
    assert _fingerprint_mutable(idx) == before  # suppressed: no rewrite
    assert idx.rewrite_pressure(tight) > 0      # still pending for maint
    idx.search(data[:8], tight)                 # default path DOES rewrite
    assert _fingerprint_mutable(idx) != before


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="sharded purity needs >= 2 devices (CI sets "
    "xla_force_host_platform_device_count=8)",
)
def test_sharded_mutable_read_path_is_pure_under_concurrency(dataset):
    from repro.index.sharded_mutable import ShardedMutableHilbertIndex
    from repro.launch.mesh import data_mesh

    data, queries = dataset
    mesh = data_mesh(jax.device_count())
    idx = ShardedMutableHilbertIndex(
        CFG, mesh=mesh, buffer_capacity=64, max_segments=8
    )
    ids = idx.insert(data[:1200])  # seals generations (64-row buffers)
    idx.delete(ids[::7])
    idx.insert(data[1200:1500])

    def fingerprint():
        segs = tuple(
            (id(seg), seg.gen, seg.dead_cache, seg.dead_epoch,
             seg.ids_host.tobytes())
            for seg in idx.segments
        )
        return (
            int(idx._rr), int(idx._gen), int(idx._lsm.next_id),
            int(idx._lsm.delete_epoch), idx._lsm.alive.tobytes(),
            None if idx._buf_pts is None else idx._buf_pts.tobytes(),
            None if idx._buf_ids is None else idx._buf_ids.tobytes(),
            idx._buf_count.tobytes(),
            idx._alive_key, id(idx._alive_dev), id(idx._dev_buf),
            frozenset(idx._chunk_fns.keys()),  # recency ORDER exempt
            segs,
        )

    want_i, want_d = idx.search(queries, SP, allow_rewrite=False)  # warm
    want_i, want_d = np.asarray(want_i), np.asarray(want_d)
    before = fingerprint()

    def do_search(i, j):
        ids_, dists_ = idx.search(queries, SP, allow_rewrite=False)
        return np.asarray(ids_), np.asarray(dists_)

    results = _hammer(do_search, n_threads=3, n_iters=4)
    # last_dispatch_count is the DOCUMENTED exemption (diagnostic scalar,
    # assigned once at search end) — everything else must be untouched
    assert fingerprint() == before
    for per_thread in results:
        for ids_, dists_ in per_thread:
            np.testing.assert_array_equal(ids_, want_i)
            np.testing.assert_array_equal(dists_, want_d)


# -- the stress battery ------------------------------------------------------


def test_stress_readers_writer_and_epoch_swaps(dataset, deadlock_watchdog):
    """Barrier-started readers + a paced writer + forced maintenance.

    Per round: writer burst (concurrent with readers) -> writer
    quiesces -> probe window (readers still hammering; probe tickets
    recorded with the epoch's index) -> forced maintenance swap.  After
    three rounds:

    * >= 3 epoch swaps happened,
    * every admitted ticket completed (zero dropped acks),
    * every probe ticket is BIT-EQUAL to a direct search on the exact
      index version (epoch) that served it — the old epoch's index is
      never mutated again once the writer quiesced, so the comparison is
      exact even though the engine swapped on.
    """
    deadlock_watchdog(300.0)
    data, queries = dataset
    idx = _mutable(data, n=1000, deletes=False)
    rng = np.random.default_rng(42)
    extra = rng.normal(size=(2000, D)).astype(np.float32)
    eng = RetrievalEngine(
        idx, SP, maintenance=None, serve_threads=2, max_batch=16,
        start=True,
    )
    stop = threading.Event()
    reader_errors = []
    reader_counts = [0] * 3
    barrier = threading.Barrier(len(reader_counts) + 1)

    def reader(i):
        r = np.random.default_rng(i)
        try:
            barrier.wait(timeout=30)
            while not stop.is_set():
                a = int(r.integers(0, Q - 8))
                t = eng.submit(queries[a : a + 8])
                ids, dists = t.result(timeout=120)
                assert ids.shape == (8, SP.k)
                assert dists.shape == (8, SP.k)
                reader_counts[i] += 1
        except BaseException as e:
            reader_errors.append(e)
            stop.set()

    threads = [
        threading.Thread(target=reader, args=(i,), daemon=True)
        for i in range(len(reader_counts))
    ]
    for t in threads:
        t.start()
    barrier.wait(timeout=30)

    probes = []  # (ticket, expected_epoch, index_version)
    swaps = 0
    off = 0
    try:
        for _ in range(3):
            # writer burst: inserts + deletes race the readers
            for _ in range(2):
                new_ids = eng.insert(extra[off : off + 300])
                off += 300
                eng.delete(new_ids[::5])
            # writer quiesces; the CURRENT epoch's index is now frozen
            epoch_index = eng.index
            epoch = eng.epoch
            round_probes = [
                eng.submit(queries[a : a + 8]) for a in range(0, 40, 8)
            ]
            for t in round_probes:
                t.result(timeout=120)
                probes.append((t, epoch, epoch_index))
            # forced maintenance: compact the shadow, replay, swap
            assert eng.maintain_once(force=True)
            swaps += 1
            assert eng.epoch == epoch + 1
    finally:
        stop.set()
        for t in threads:
            t.join(60)
        eng.stop()

    assert not reader_errors, reader_errors[:1]
    assert not any(t.is_alive() for t in threads), "reader threads hung"
    assert swaps >= 3
    assert all(c > 0 for c in reader_counts)
    # zero dropped acks: everything admitted was completed (no deadlines
    # in this battery, so nothing may expire either)
    m = eng.metrics
    assert m.counter("completed") == m.counter("admitted")
    assert m.counter("deadline_expired") == 0
    assert eng._write_log is None  # replay log closed after every cycle
    # per-epoch bit-equality: the engine searched with
    # allow_rewrite=False, so the direct comparison does too
    for t, epoch, epoch_index in probes:
        assert t.epoch == epoch
        want_i, want_d = epoch_index.search(
            t.queries, SP, allow_rewrite=False
        )
        np.testing.assert_array_equal(t.ids, np.asarray(want_i))
        np.testing.assert_array_equal(t.dists, np.asarray(want_d))


def test_serve_threads_share_the_read_side(dataset, deadlock_watchdog):
    """With serve_threads=2 and no writer, a burst drains with both
    workers searching CONCURRENTLY under the shared read lock — and the
    results are still bit-equal to direct search."""
    deadlock_watchdog(180.0)
    data, queries = dataset
    idx = _mutable(data, n=800, deletes=False)
    want_i, want_d = idx.search(queries[:8], SP, allow_rewrite=False)
    with RetrievalEngine(
        idx, SP, maintenance=None, serve_threads=2, max_batch=8,
        start=True,
    ) as eng:
        tickets = [eng.submit(queries[:8]) for _ in range(24)]
        for t in tickets:
            ids, dists = t.result(timeout=120)
            np.testing.assert_array_equal(ids, np.asarray(want_i))
            np.testing.assert_array_equal(dists, np.asarray(want_d))
    s = eng._serve_lock.stats()
    assert s["read_acquisitions"] >= len(tickets) / eng.max_batch


def test_edf_order_is_visible_in_step_mode(dataset):
    """A near-deadline ticket submitted AFTER a far-deadline bulk one is
    served first (the FIFO head-blocking case EDF removes)."""
    data, queries = dataset
    idx = _mutable(data, n=600, deletes=False)
    eng = RetrievalEngine(idx, SP, maintenance=None, max_batch=8)
    bulk = eng.submit(queries[:8], deadline_ms=60_000.0)
    urgent = eng.submit(queries[8:16], deadline_ms=500.0)
    assert eng.step() > 0
    assert urgent.done and not bulk.done
    assert eng.step() > 0
    assert bulk.done
    bulk.result(0), urgent.result(0)
