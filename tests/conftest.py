"""Shared fixtures: a deadlock watchdog for the concurrency suite.

A hung lock-ordering bug presents as a test that never finishes — in CI
that is a 6-hour timeout with zero diagnostics.  ``deadlock_watchdog``
arms :func:`faulthandler.dump_traceback_later`: if the test has not
disarmed it within its budget, every thread's stack is dumped (to stderr
and, when ``REPRO_FAULTHANDLER_DUMP`` names a file, to that file so CI
can upload it as an artifact) and the process exits hard.  The dump IS
the bug report: it shows exactly which threads hold/await which locks.
"""

import faulthandler
import os

import pytest


@pytest.fixture
def deadlock_watchdog():
    """Arm a per-test wall-clock budget; dump all thread stacks on breach.

    Usage::

        def test_stress(deadlock_watchdog):
            deadlock_watchdog(120.0)   # seconds
            ... spawn threads, join them ...

    Disarms automatically at teardown; a test that returns beat the
    clock.  ``exit=True`` because a deadlocked process cannot run
    teardown — a hard exit with stacks beats a silent CI timeout.
    """
    dump_path = os.environ.get("REPRO_FAULTHANDLER_DUMP")
    dump_file = open(dump_path, "w") if dump_path else None

    def arm(timeout_s: float) -> None:
        if dump_file is not None:
            faulthandler.dump_traceback_later(
                timeout_s, exit=True, file=dump_file
            )
        else:
            faulthandler.dump_traceback_later(timeout_s, exit=True)

    try:
        yield arm
    finally:
        faulthandler.cancel_dump_traceback_later()
        if dump_file is not None:
            dump_file.close()
