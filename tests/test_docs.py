"""Docs stay honest: code blocks parse, doctests pass, links resolve.

Runs ``scripts/check_docs.py`` in-process over README.md + docs/*.md so
the tier-1 suite catches doc rot (broken cross-references, stale code
samples) the same way CI's docs job does, plus unit tests for the
checker's own slug/link logic.
"""

import glob
import importlib.util
import os

_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(_ROOT, "scripts", "check_docs.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_repo_docs_are_clean():
    mod = _checker()
    paths = [
        os.path.join(_ROOT, "README.md"),
        os.path.join(_ROOT, "ROADMAP.md"),
    ] + sorted(glob.glob(os.path.join(_ROOT, "docs", "*.md")))
    assert len(paths) >= 3, "expected README + docs tree"
    problems = []
    for p in paths:
        problems.extend(mod.check_file(p))
    assert not problems, "\n".join(problems)


def test_checker_flags_bad_python_block(tmp_path):
    mod = _checker()
    bad = tmp_path / "bad.md"
    bad.write_text("# T\n\n```python\ndef broken(:\n```\n")
    problems = mod.check_file(str(bad))
    assert any("does not parse" in p for p in problems)


def test_checker_flags_broken_link_and_anchor(tmp_path):
    mod = _checker()
    other = tmp_path / "other.md"
    other.write_text("# Real Heading\n")
    doc = tmp_path / "doc.md"
    doc.write_text(
        "# Title\n\n[ok](other.md#real-heading)\n"
        "[gone](missing.md)\n[bad](other.md#nope)\n"
    )
    problems = mod.check_file(str(doc))
    assert any("broken link" in p and "missing.md" in p for p in problems)
    assert any("broken anchor" in p and "nope" in p for p in problems)
    assert not any("real-heading" in p for p in problems)


def test_checker_flags_stale_bench_claims(tmp_path):
    mod = _checker()
    art = tmp_path / "BENCH_demo.json"
    art.write_text('{"latency": {"p50_ms": 180.7, "p99_ms": 193.6}}')
    doc = tmp_path / "doc.md"

    # matching claims (exact, rounded, with/without space) pass
    doc.write_text(
        "# T\n\n`BENCH_demo.json` shows p50 180.7ms and p99 194 ms.\n"
    )
    assert not mod.check_file(str(doc))

    # a drifted figure is flagged; knob names like deadline_ms are not
    doc.write_text(
        "# T\n\n`BENCH_demo.json` once showed 577ms; deadline_ms=5000.\n"
    )
    problems = mod.check_file(str(doc))
    assert any("577ms" in p and "stale" in p for p in problems)
    assert not any("5000" in p for p in problems)

    # the opt-out marker silences the paragraph
    doc.write_text(
        "# T\n\n<!-- bench-claims: ignore -->\n"
        "`BENCH_demo.json` historically read 577ms.\n"
    )
    assert not mod.check_file(str(doc))

    # naming a missing artifact is itself a problem
    doc.write_text("# T\n\nSee `BENCH_ghost.json` for 12ms.\n")
    problems = mod.check_file(str(doc))
    assert any("BENCH_ghost.json" in p and "no such artifact" in p
               for p in problems)


def test_checker_runs_doctest_blocks(tmp_path):
    mod = _checker()
    doc = tmp_path / "dt.md"
    doc.write_text("```python\n>>> 1 + 1\n3\n```\n")
    problems = mod.check_file(str(doc))
    assert any("doctest failed" in p for p in problems)
    doc.write_text("```python\n>>> 1 + 1\n2\n```\n")
    assert not mod.check_file(str(doc))
