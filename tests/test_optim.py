"""Optimizer unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # guarded dev-only import

from repro.optim import OptimizerConfig, apply_updates, init_opt_state, lr_at


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-4          # end of warmup
    assert lrs[-1] <= 1.2e-4                  # decayed to ~min_lr_frac
    assert max(lrs) <= 1e-3 + 1e-9


def test_adamw_reduces_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_clipping_bounds_update():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=0, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = apply_updates(params, g, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_preserves_signal(seed):
    """bf16 compression with error feedback: accumulated sent ≈ accumulated
    true gradient (the residual carries, it never vanishes)."""
    cfg = OptimizerConfig(compression="bf16", clip_norm=1e9, lr=0.0,
                          weight_decay=0.0, warmup_steps=0)
    rng = np.random.default_rng(seed)
    params = {"w": jnp.zeros((32,))}
    state = init_opt_state(params, cfg)
    total_err = None
    for _ in range(5):
        g = {"w": jnp.asarray(rng.normal(size=32).astype(np.float32) * 1e-3)}
        params, state, _ = apply_updates(params, g, state, cfg)
    # the carried residual is bounded by one quantization step, not growing
    err = np.abs(np.asarray(state["err"]["w"]))
    assert err.max() < 1e-4
