"""Forest / Algorithm-1 search / Algorithm-2 graph behaviour tests.

Validates the paper's claims at container scale, at the paper's
dimensionality (d=384, MiniLM-style geometry — see
``ann_datasets.lowrank_embeddings`` for why intrinsic dimension matters):
  * Task-1-style search hits recall@30 > 0.7 with a modest forest.
  * Task-2-style graph construction hits recall@15 > 0.8.
  * Recall is monotone in the number of trees/orders (the paper's
    "using more trees improves recall").
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knn_graph, quantize, search, sketch
from repro.core.types import ForestConfig, GraphParams, QuantizerConfig, SearchParams
from repro.data import ann_datasets

N, D, Q = 12000, 384, 200


@pytest.fixture(scope="module")
def dataset():
    # Held-out queries from the SAME distribution (the challenge's regime:
    # PUBMED23 queries are abstracts like the indexed ones).
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=48, seed=0
    )
    gt, _ = ann_datasets.exact_knn(data, queries, 30)
    return data, queries, gt


@pytest.fixture(scope="module")
def index(dataset):
    data, _, _ = dataset
    cfg = ForestConfig(n_trees=16, bits=4, key_bits=448, leaf_size=32, seed=0)
    return search.build_index(jnp.asarray(data), cfg), cfg


def test_task1_recall_band(dataset, index):
    data, queries, gt = dataset
    idx, cfg = index
    params = SearchParams(k1=48, k2=384, h=2, k=30)
    ids, dists = search.search(idx, jnp.asarray(queries), params, cfg)
    rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
    assert rec > 0.7, f"recall@30={rec}"
    # distances are sorted ascending
    d = np.asarray(dists)
    assert np.all(np.diff(d, axis=1) >= -1e-5)


def test_no_duplicate_results(dataset, index):
    data, queries, gt = dataset
    idx, cfg = index
    params = SearchParams(k1=48, k2=384, h=2, k=30)
    ids, _ = search.search(idx, jnp.asarray(queries), params, cfg)
    ids = np.asarray(ids)
    for row in ids:
        assert len(set(row.tolist())) == len(row)


def test_recall_monotone_in_trees(dataset, index):
    """Paper §2: "Using more trees improves recall"."""
    data, queries, gt = dataset
    idx16, cfg16 = index
    recalls = []
    for n_trees in (2, 6):
        cfg = ForestConfig(n_trees=n_trees, bits=4, key_bits=448, leaf_size=32)
        idx = search.build_index(jnp.asarray(data), cfg)
        params = SearchParams(k1=48, k2=384, h=2, k=30)
        ids, _ = search.search(idx, jnp.asarray(queries), params, cfg)
        recalls.append(ann_datasets.recall_at_k(np.asarray(ids), gt))
    ids, _ = search.search(
        idx16, jnp.asarray(queries), SearchParams(k1=48, k2=384, h=2, k=30), cfg16
    )
    recalls.append(ann_datasets.recall_at_k(np.asarray(ids), gt))
    assert recalls[0] < recalls[-1]
    assert recalls[-1] == max(recalls)


def test_task2_graph_recall_band():
    data = ann_datasets.lowrank_embeddings(8000, D, n_clusters=32, seed=3)
    gt = ann_datasets.exact_knn_graph(data, 15)
    params = GraphParams(n_orders=20, k1=48, k2=96, k=15, seed=0)
    ids, dists = knn_graph.build_knn_graph(
        jnp.asarray(data), params, forest_cfg=ForestConfig(bits=4, key_bits=448)
    )
    rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
    assert rec > 0.8, f"recall@15={rec}"
    ids = np.asarray(ids)
    # no self edges, no duplicates
    assert not np.any(ids == np.arange(len(data))[:, None])
    for row in ids[:500]:
        assert len(set(row.tolist())) == len(row)


def test_memory_report_shared_bit(index):
    idx, _ = index
    rep = idx.memory_report()
    # combined < sketches + codes (the shared-MSB saving), all positive
    assert rep["combined_stage2_bytes"] < rep["sketch_bytes"] + rep["quantized_bytes"]
    assert rep["forest_bytes"] > 0


def test_quantizer_roundtrip_and_shared_msb():
    data = ann_datasets.gaussian(5000, 24, seed=1)
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    recon = quantize.decode(quant, codes)
    # reconstruction error bounded by cell widths
    err = np.abs(np.asarray(recon) - data).mean()
    assert err < 0.2, err
    # MSB == median bit
    sk_codes = np.asarray(sketch.sketches_from_codes(codes))
    sk_direct = np.asarray(sketch.make_sketches(quant, jnp.asarray(data)))
    mismatch = (sk_codes != sk_direct).mean()
    assert mismatch < 1e-3  # boundary ties only
