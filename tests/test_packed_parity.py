"""Packed-resident code-path parity (PR 3 tentpole).

The index now stores ``codes_master`` nibble-packed (n, ceil(d/8)) uint32
and serves search through one fused dispatch per chunk.  These tests pin
the invariants that make that safe:

* pack/unpack is a lossless bijection (hypothesis property);
* packed ADC == unpacked ADC bit-for-bit (the XLA route unpacks losslessly);
* full ``search()`` is bit-identical between the fused packed path and the
  per-tree-loop unpacked reference, on random AND adversarial tied-distance
  inputs;
* v1 (unpacked uint8) checkpoint bundles load with a transparent repack;
* the paper memory model and the resident actuals agree after packing, and
  a store_points=False index at d=384 lost >= 40% resident RAM vs the
  unpacked layout;
* power-of-two query bucketing keeps results exact at every batch size.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # guarded dev-only import

from repro import checkpoint
from repro.core import quantize, search as search_lib, sketch
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    SearchParams,
)

RNG = np.random.default_rng(0)

N, D, Q = 3000, 64, 37  # Q deliberately not a power of two

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=256, leaf_size=16, seed=0)
)
SP = SearchParams(k1=16, k2=64, h=2, k=10)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return jnp.asarray(data), jnp.asarray(queries)


@pytest.fixture(scope="module")
def index(dataset):
    data, _ = dataset
    return HilbertIndex.build(data, CFG)


# ---------------------------------------------------------------------------
# pack / unpack round-trip
# ---------------------------------------------------------------------------


def test_resident_codes_are_packed(index):
    assert index.codes_master.dtype == jnp.uint32
    assert index.codes_master.shape == (N, -(-D // 8))
    assert index.dim == D


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 60),
    d=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(n, d, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 16, size=(n, d), dtype=np.uint8))
    packed = quantize.pack_codes(codes)
    assert packed.shape == (n, -(-d // 8)) and packed.dtype == jnp.uint32
    back = quantize.unpack_codes(packed, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_unpack_codes_batched_leading_shape():
    codes = jnp.asarray(RNG.integers(0, 16, size=(24, 40), dtype=np.uint8))
    packed = quantize.pack_codes(codes)
    windows = packed.reshape(4, 6, -1)  # (Q, C, W)
    back = quantize.unpack_codes(windows, 40)
    np.testing.assert_array_equal(
        np.asarray(back).reshape(24, 40), np.asarray(codes)
    )


# ---------------------------------------------------------------------------
# packed vs unpacked ADC distance — bit identity
# ---------------------------------------------------------------------------


def test_adc_distance_packed_bit_identical():
    q, c, d = 9, 33, 48
    data = RNG.normal(size=(c, d)).astype(np.float32)
    queries = jnp.asarray(RNG.normal(size=(q, d)).astype(np.float32))
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    cand = jnp.broadcast_to(codes[None], (q, c, d))  # (Q, C, d)
    packed = jax.vmap(quantize.pack_codes)(cand)
    got = quantize.adc_distance_packed(quant, queries, packed, d=d)
    ref = quantize.adc_distance(quant, queries, cand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_stage2_packed_vs_unpacked_bit_identical(dataset, index):
    _, queries = dataset
    fcfg = CFG.forest
    f = index.forest
    qsk = sketch.make_sketches(index.quant, queries)
    best_pos = jnp.full((Q, SP.k2), -1, jnp.int32)
    best_dist = jnp.full((Q, SP.k2), jnp.int32(2**30), jnp.int32)
    for t in range(f.n_trees):
        best_pos, best_dist = search_lib.stage1_tree_merge(
            queries, qsk, best_pos, best_dist,
            f.orders[t], f.directories[t], f.lo, f.hi, f.perms[t], f.flips[t],
            index.master_rank, index.sketches_master,
            bits=fcfg.bits, key_bits=fcfg.key_bits,
            leaf_size=fcfg.leaf_size, k1=SP.k1, k2=SP.k2,
        )
    ids_p, d2_p = search_lib.stage2_packed_windows(
        queries, best_pos, index.codes_master, index.master_order, index.quant,
        h=SP.h, k=SP.k,
    )
    codes_u8 = quantize.unpack_codes(index.codes_master, index.dim)
    ids_u, d2_u = search_lib.stage2_expand_rank(
        queries, best_pos, codes_u8, index.master_order, index.quant,
        h=SP.h, k=SP.k,
    )
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_u))
    np.testing.assert_array_equal(np.asarray(d2_p), np.asarray(d2_u))


# ---------------------------------------------------------------------------
# full search() bit identity: fused packed vs per-tree-loop unpacked
# ---------------------------------------------------------------------------


def _assert_search_paths_identical(idx, queries, params):
    ids_f, d2_f = idx.search(queries, params, backend="xla")
    ids_r, d2_r = idx.search(queries, params, backend="xla", fused=False)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(d2_f), np.asarray(d2_r))
    return ids_f, d2_f


def test_search_bit_identity_random(dataset, index):
    _, queries = dataset
    _assert_search_paths_identical(index, queries, SP)


def test_search_bit_identity_adversarial_ties(dataset):
    """Tied distances everywhere: duplicated points on a coarse lattice.

    Every duplicated point produces exactly tied ADC distances, so any
    tie-breaking divergence between the packed and unpacked paths would
    surface here as an id mismatch.
    """
    data, _ = dataset
    lattice = np.round(np.asarray(data) * 2) / 2
    dup = np.concatenate([lattice[: N // 2], lattice[: N // 2]])  # exact dups
    idx = HilbertIndex.build(jnp.asarray(dup.astype(np.float32)), CFG)
    queries = jnp.asarray(dup[:29].astype(np.float32))  # queries ON the data
    ids, d2 = _assert_search_paths_identical(idx, queries, SP)
    assert np.isfinite(np.asarray(d2)).all()


def test_search_bit_identity_small_n_edge_windows():
    """n smaller than the ±h window forces the shifted-window edge logic."""
    pts = jnp.asarray(RNG.normal(size=(7, 16)).astype(np.float32))
    cfg = IndexConfig(
        forest=ForestConfig(n_trees=2, bits=3, key_bits=32, leaf_size=2, seed=1)
    )
    idx = HilbertIndex.build(pts, cfg)
    queries = jnp.asarray(RNG.normal(size=(5, 16)).astype(np.float32))
    params = SearchParams(k1=4, k2=8, h=4, k=3)  # 2h+1 > n
    ids, _ = _assert_search_paths_identical(idx, queries, params)
    assert ((np.asarray(ids) >= 0) & (np.asarray(ids) < 7)).all()


def test_k_larger_than_candidate_pool_pads(dataset):
    """k > k2*min(2h+1, n): top-k runs over the pool, tail pads -1/+inf.

    Regression: the shifted-window expansion shrinks the stage-2 pool to
    ``k2*min(2h+1, n)``, which on a tiny index (or a tiny heavily-
    tombstoned mutable segment queried with an inflated k) can fall below
    k — this used to crash lax.top_k.
    """
    pts = jnp.asarray(RNG.normal(size=(3, 16)).astype(np.float32))
    cfg = IndexConfig(
        forest=ForestConfig(n_trees=2, bits=3, key_bits=32, leaf_size=2, seed=0)
    )
    idx = HilbertIndex.build(pts, cfg)
    queries = jnp.asarray(RNG.normal(size=(4, 16)).astype(np.float32))
    params = SearchParams(k1=4, k2=8, h=4, k=30)  # pool = 8*3 = 24 < k
    ids, d2 = _assert_search_paths_identical(idx, queries, params)
    ids, d2 = np.asarray(ids), np.asarray(d2)
    assert ids.shape == (4, 30) and d2.shape == (4, 30)
    assert (ids[:, -6:] == -1).all() and np.isinf(d2[:, -6:]).all()
    # the 3 real points lead each row with finite distances
    assert np.isfinite(d2[:, :3]).all()
    assert ((ids[:, :3] >= 0) & (ids[:, :3] < 3)).all()


def test_pallas_backend_matches_xla_ids(dataset, index):
    """Kernel route (interpret mode on CPU) agrees with XLA on results."""
    _, queries = dataset
    ids_x, d2_x = index.search(queries, SP, backend="xla")
    ids_p, d2_p = index.search(queries, SP, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ids_x), np.asarray(ids_p))
    np.testing.assert_allclose(
        np.asarray(d2_x), np.asarray(d2_p), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# v1 -> v2 checkpoint upgrade
# ---------------------------------------------------------------------------


def _write_v1_bundle(index, path):
    """Replicate the PR-1/2 on-disk format: unpacked uint8 codes, fmt 1."""
    bundle = dict(index._array_bundle())
    bundle["codes_master"] = quantize.unpack_codes(
        index.codes_master, index.dim
    )
    extra = {
        "kind": "hilbert_index",
        "format_version": 1,
        "config": index.config.to_dict(),
        "has_points": index.points is not None,
        "n_points": int(index.n_points),
        "dim": int(index.dim),
        "extra_arrays": [],
    }
    checkpoint.save(path, step=0, tree=bundle, extra=extra)


def test_v1_bundle_loads_and_repacks(tmp_path, dataset, index):
    _, queries = dataset
    path = str(tmp_path / "v1")
    _write_v1_bundle(index, path)
    # sanity: the bundle on disk really is v1/unpacked
    with open(os.path.join(path, "step_00000000", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["extra"]["format_version"] == 1
    assert manifest["leaves"]["['codes_master']"][1] == "uint8"

    loaded = HilbertIndex.load(path)
    assert loaded.codes_master.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(loaded.codes_master), np.asarray(index.codes_master)
    )
    ids, d2 = index.search(queries, SP)
    ids2, d22 = loaded.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids2))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d22))


def test_v2_roundtrip_stays_packed(tmp_path, index):
    path = str(tmp_path / "v2")
    index.save(path)
    step = checkpoint.latest_step(path)
    with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["extra"]["format_version"] == 2
    assert manifest["leaves"]["['codes_master']"][1] == "uint32"
    loaded = HilbertIndex.load(path)
    np.testing.assert_array_equal(
        np.asarray(loaded.codes_master), np.asarray(index.codes_master)
    )


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------


def test_paper_model_matches_resident_actuals(index):
    rep = index.memory_report()
    assert rep["quantized_bytes"] == rep["codes_bytes"]
    # the shared helper IS the legacy container's report
    legacy = search_lib.paper_memory_model(
        index.n_points, index.dim,
        int(np.prod(index.sketches_master.shape)) * 4,
        index.forest.memory_bytes(),
    )
    for key, val in legacy.items():
        assert rep[key] == val


def test_resident_bytes_drop_at_paper_dim():
    """store_points=False at d=384: packing must save >= 40% resident RAM."""
    n, d = 12000, 384
    data = ann_datasets.lowrank_embeddings(n, d, n_clusters=16, seed=2)
    cfg = IndexConfig(
        forest=ForestConfig(n_trees=4, bits=4, key_bits=448, leaf_size=32),
        store_points=False,
    )
    idx = HilbertIndex.build(jnp.asarray(data), cfg)
    rep = idx.memory_report()
    assert rep["points_bytes"] == 0
    # what the same index cost when codes were resident unpacked uint8
    unpacked_baseline = rep["resident_bytes"] - rep["codes_bytes"] + n * d
    drop = 1.0 - rep["resident_bytes"] / unpacked_baseline
    assert drop >= 0.40, f"resident drop {drop:.1%} < 40%"


# ---------------------------------------------------------------------------
# pow2 bucketing (serving recompile hazard)
# ---------------------------------------------------------------------------


def test_bucketed_batches_exact_at_every_size(dataset, index):
    _, queries = dataset
    full_ids, full_d2 = index.search(queries, SP)
    for m in (1, 2, 3, 5, 8, 13, 21, Q):
        ids, d2 = index.search(queries[:m], SP)
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(full_ids[:m])
        )
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(full_d2[:m]))


def test_pow2_bucket_policy():
    from repro.index.facade import _pow2_bucket

    assert _pow2_bucket(1, 2048) == 1
    assert _pow2_bucket(3, 2048) == 4
    assert _pow2_bucket(33, 2048) == 64
    assert _pow2_bucket(2048, 2048) == 2048
    assert _pow2_bucket(1500, 2048) == 2048
    assert _pow2_bucket(5, 4) == 4  # cap wins


def test_empty_query_batch(dataset, index):
    """An idle decode step (0 queries) returns well-typed (0, k) results."""
    _, queries = dataset
    ids, d2 = index.search(queries[:0], SP)
    assert np.asarray(ids).shape == (0, SP.k)
    assert np.asarray(d2).shape == (0, SP.k)
    assert np.asarray(ids).dtype == np.int32


def test_legacy_shim_pack_cache_evicts():
    """The legacy-shim pack cache drops entries when the index dies."""
    import gc
    import warnings

    from repro.core.search import _PACKED_SHIM_CACHE

    data = jnp.asarray(RNG.normal(size=(300, 16)).astype(np.float32))
    queries = jnp.asarray(RNG.normal(size=(4, 16)).astype(np.float32))
    fcfg = ForestConfig(n_trees=2, bits=3, key_bits=32, leaf_size=4, seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for _ in range(3):
            legacy = search_lib.build_index(data, fcfg)
            search_lib.search(
                legacy, queries, SearchParams(k1=4, k2=8, h=1, k=3), fcfg
            )
            search_lib.search(  # second call hits the cache
                legacy, queries, SearchParams(k1=4, k2=8, h=1, k=3), fcfg
            )
            del legacy
            gc.collect()
    assert len(_PACKED_SHIM_CACHE) == 0


def test_chunked_equals_unchunked(dataset, index):
    _, queries = dataset
    ids_a, d2_a = index.search(queries, SP, query_chunk=8)
    ids_b, d2_b = index.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d2_a), np.asarray(d2_b))
