"""Hypothesis property tests for the search/graph invariants."""

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # guarded dev-only import

from repro.core import hilbert, search
from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(300, 900),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_search_invariants(n, d, seed):
    """Results are valid ids, deduped, with ascending true-ish distances."""
    data = ann_datasets.lowrank_embeddings(n, d, n_clusters=8, r=4, seed=seed)
    queries = data[:16] + 1e-3  # near-copies: top-1 should often be the row
    cfg = ForestConfig(n_trees=4, bits=4, key_bits=min(64, d * 4),
                       leaf_size=16, seed=0)
    idx = search.build_index(jnp.asarray(data), cfg)
    params = SearchParams(k1=16, k2=64, h=1, k=8)
    ids, dists = search.search(idx, jnp.asarray(queries), params, cfg)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ((ids >= 0) & (ids < n)).all()
    for row in ids:
        assert len(set(row.tolist())) == len(row)
    assert (np.diff(dists, axis=1) >= -1e-4).all()
    # a near-copy query finds its source row in the top-8 most of the time
    hits = sum(int(i in ids[i]) for i in range(16))
    assert hits >= 12, hits


@settings(max_examples=8, deadline=None)
@given(
    d=st.integers(2, 24),
    bits=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_transpose_involution_property(d, bits, seed):
    rng = np.random.default_rng(seed)
    coords = rng.integers(0, 1 << bits, size=(64, d)).astype(np.uint32)
    tr = hilbert.axes_to_transpose(jnp.asarray(coords), bits)
    back = hilbert.transpose_to_axes(tr, bits)
    np.testing.assert_array_equal(np.asarray(back), coords)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hilbert_keys_invariant_to_point_order(seed):
    """Keys are per-point functions: permuting inputs permutes keys."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(128, 12)).astype(np.float32)
    lo = jnp.full((12,), -5.0)
    hi = jnp.full((12,), 5.0)
    k1 = hilbert.hilbert_keys(jnp.asarray(pts), bits=4, key_bits=48, lo=lo, hi=hi)
    perm = rng.permutation(128)
    k2 = hilbert.hilbert_keys(jnp.asarray(pts[perm]), bits=4, key_bits=48,
                              lo=lo, hi=hi)
    np.testing.assert_array_equal(np.asarray(k1)[perm], np.asarray(k2))
