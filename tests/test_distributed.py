"""Distributed Hilbert sort / kNN graph — runs in a subprocess with 8
simulated devices so this pytest process keeps its 1-device view."""

import os
import subprocess
import sys

def test_distributed_sample_sort_and_graph():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "distributed_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in r.stdout
