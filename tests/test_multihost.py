"""Multi-host topology resolution (env parsing; no cluster needed)."""

from repro.launch.multihost import host_batch_slice, resolve_topology


def test_slurm_resolution():
    env = {"SLURM_PROCID": "3", "SLURM_NTASKS": "8",
           "SLURM_STEP_NODELIST": "gpu[003-010]"}
    t = resolve_topology(env=env)
    assert (t.host_id, t.n_hosts, t.source) == (3, 8, "slurm")
    assert t.coordinator == "gpu003:12321"


def test_gke_tpu_resolution():
    env = {"TPU_WORKER_ID": "1",
           "TPU_WORKER_HOSTNAMES": "t1k-w0,t1k-w1,t1k-w2,t1k-w3"}
    t = resolve_topology(env=env)
    assert (t.host_id, t.n_hosts, t.source) == (1, 4, "gke")
    assert t.coordinator.startswith("t1k-w0:")


def test_manual_and_single():
    t = resolve_topology(coordinator="10.0.0.1:1234", host_id=2, n_hosts=4)
    assert t.source == "manual" and t.coordinator == "10.0.0.1:1234"
    t1 = resolve_topology(env={})
    assert (t1.n_hosts, t1.source) == (1, "single")


def test_host_batch_slice_partition():
    envs = [{"SLURM_PROCID": str(i), "SLURM_NTASKS": "4",
             "SLURM_NODELIST": "n1"} for i in range(4)]
    slices = [host_batch_slice(256, resolve_topology(env=e)) for e in envs]
    covered = []
    for a, b in slices:
        covered.extend(range(a, b))
    assert covered == list(range(256))
